"""End-to-end behaviour tests: train-to-convergence, checkpoint/restart,
carbon-aware replication in the loop, and the serve launcher."""

import os

import numpy as np
import pytest


def test_training_reduces_loss(tmp_path):
    from repro.launch.train import main

    res = main([
        "--arch", "internlm2-1.8b", "--reduced", "--steps", "30",
        "--batch", "8", "--seq", "64", "--lr", "8e-3",
    ])
    losses = res["losses"]
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.3, (first, last)


def test_checkpoint_restart_continues(tmp_path):
    from repro.launch.train import main

    ckpt = str(tmp_path / "run1")
    main(["--arch", "mamba2-130m", "--reduced", "--steps", "6",
          "--batch", "4", "--seq", "32", "--ckpt-dir", ckpt,
          "--ckpt-every", "3"])
    # Crash-restart: new process picks up from the final checkpoint.
    res = main(["--arch", "mamba2-130m", "--reduced", "--steps", "10",
                "--batch", "4", "--seq", "32", "--ckpt-dir", ckpt])
    # 6 steps done in run 1 -> run 2 executes exactly 4 more.
    assert len(res["losses"]) == 4


def test_train_with_replication(tmp_path):
    from repro.launch.train import main

    ckpt = str(tmp_path / "run2")
    res = main(["--arch", "mamba2-130m", "--reduced", "--steps", "4",
                "--batch", "4", "--seq", "32", "--ckpt-dir", ckpt,
                "--ckpt-every", "2", "--replicate-checkpoints"])
    assert res["final_loss"] is not None


def test_serve_launcher_runs():
    from repro.launch.serve import main

    res = main(["--arch", "internlm2-1.8b", "--reduced", "--requests", "3",
                "--max-new", "4", "--max-batch", "2"])
    assert res["tokens"] == 3 * 4


def test_grad_accumulation_equivalence():
    """microbatches=2 must match microbatches=1 on the same global batch."""
    import dataclasses as dc

    import jax
    import jax.numpy as jnp

    from repro.configs import OptimizerConfig, TrainConfig, registry
    from repro.train import init_state, make_train_step

    cfg = registry.get("internlm2-1.8b").model(reduced=True)
    cfg = dc.replace(cfg, compute_dtype="float32")
    opt = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=4,
                          grad_clip_norm=0.0)
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
    }
    outs = []
    for k in (1, 2):
        tcfg = TrainConfig(global_batch=4, seq_len=32, microbatches=k,
                           optimizer=opt)
        state = init_state(key, cfg, tcfg)
        step = jax.jit(make_train_step(cfg, tcfg))
        new_state, metrics = step(state, batch)
        outs.append((new_state, float(metrics["loss"])))
    (s1, l1), (s2, l2) = outs
    assert l1 == pytest.approx(l2, rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)

"""Ragged-fleet batching: bucketing, padding invariants, per-problem parity.

The acceptance bar (ISSUE 4): a mixed-shape fleet (>=3 distinct
(n_jobs, n_slots) shapes) through ``plan_batch`` matches per-problem
``lints.solve`` objectives to <=1e-9 relative, padded jobs carry zero rate
everywhere, and per-problem meta (iterations/converged/batch_index)
survives bucketing.
"""

import numpy as np
import pytest

from repro.core import api, lints, problem, ragged, trace
from repro.core.feasibility import check_plan
from repro.core.pdhg import PDHGConfig
from repro.core.plan import InfeasibleError

PATH = ("US-NM", "US-WY", "US-SD")

PDHG_FAST = PDHGConfig(max_iters=20_000, check_every=200, tol=2e-5,
                       use_kernel=False)
CFG = lints.LinTSConfig(backend="pdhg", pdhg=PDHG_FAST)


def _mixed_fleet():
    """Four distinct (n_jobs, n_slots) shapes, mixed capacities.  Buckets
    solve at their members' max extent, so this exercises in-bucket job
    padding (5->8 alongside the 8x288 member), in-bucket slot padding
    (240->244 alongside the 6x244 member, both under quantized key
    (8, 256)), and singleton buckets that solve at their exact shape."""
    specs = [(5, 72, 0.5), (8, 60, 0.75), (3, 48, 0.5), (8, 72, 0.5),
             (6, 61, 0.5)]
    probs = []
    for s, (n_jobs, hours, cap) in enumerate(specs):
        traces = trace.make_trace_set(PATH, hours=hours, seed=s)
        reqs = problem.paper_workload(
            n_jobs=n_jobs, seed=s,
            deadline_range_h=(int(hours * 0.6), hours - 1))
        probs.append(lints.build(reqs, traces, cap))
    return probs


# ---------------------------------------------------------------- bucketing

def test_bucket_shape_quantizes():
    assert ragged.bucket_shape(5, 288) == (8, 288)
    assert ragged.bucket_shape(8, 240) == (8, 256)
    assert ragged.bucket_shape(3, 192) == (4, 192)
    assert ragged.bucket_shape(1, 1) == (4, 32)
    assert ragged.bucket_shape(9, 289) == (16, 320)
    with pytest.raises(ValueError):
        ragged.bucket_shape(0, 32)


def test_pad_problem_invariants():
    p = _mixed_fleet()[0]                      # (5, 288)
    padded = ragged.pad_problem(p, 8, 320)
    assert (padded.n_jobs, padded.n_slots) == (8, 320)
    np.testing.assert_array_equal(padded.cost[:5, :288], p.cost)
    np.testing.assert_array_equal(padded.mask[:5, :288], p.mask)
    assert not padded.mask[5:, :].any()        # padded jobs: no usable slot
    assert not padded.mask[:, 288:].any()      # padded slots: masked for all
    assert (padded.size_bits[5:] == 0).all()
    assert (padded.cost[5:, :] == 0).all() and (padded.cost[:, 288:] == 0).all()
    np.testing.assert_array_equal(padded.size_bits[:5], p.size_bits)
    np.testing.assert_array_equal(padded.deadlines[:5], p.deadlines)
    assert padded.capacity_bps == p.capacity_bps
    assert padded.rate_cap_bps == p.rate_cap_bps
    # identity when the shape already matches
    assert ragged.pad_problem(p, 5, 288) is p
    with pytest.raises(ValueError):
        ragged.pad_problem(p, 4, 288)


# ------------------------------------------------------------------- parity

@pytest.fixture(scope="module")
def fleet_and_plans():
    probs = _mixed_fleet()
    plans = api.get_policy("lints_pdhg", config=CFG).plan_batch(probs)
    return probs, plans


def test_mixed_fleet_matches_per_problem_solve(fleet_and_plans):
    """>=3 distinct shapes; batch objectives match solo ``lints.solve`` to
    <=1e-9 relative (the ISSUE 4 acceptance bar)."""
    probs, plans = fleet_and_plans
    assert len({(p.n_jobs, p.n_slots) for p in probs}) >= 3
    for p, plan in zip(probs, plans):
        solo = api.get_policy("lints_pdhg", config=CFG).plan(p)
        ref = solo.objective(p)
        assert plan.objective(p) == pytest.approx(ref, rel=1e-9)
        assert plan.rho_bps.shape == (p.n_jobs, p.n_slots)
        assert check_plan(p, plan.rho_bps, rel_tol=1e-5).feasible


def test_per_problem_meta_survives_bucketing(fleet_and_plans):
    probs, plans = fleet_and_plans
    for i, (p, plan) in enumerate(zip(probs, plans)):
        assert plan.meta["batch_index"] == i
        assert plan.meta["batch_size"] == len(probs)
        assert plan.meta["policy"] == "lints_pdhg"
        assert plan.meta["converged"] is True
        assert plan.meta["iterations"] > 0
        bj, bs = plan.meta["bucket_shape"]
        assert plan.meta["padded_jobs"] == bj - p.n_jobs >= 0
        assert plan.meta["padded_slots"] == bs - p.n_slots >= 0
    # both in-bucket padding modes really occurred in this fleet
    assert any(plan.meta["padded_jobs"] > 0 for plan in plans)
    assert any(plan.meta["padded_slots"] > 0 for plan in plans)
    # bucket bookkeeping adds up (bucket_shape is the shared solve shape)
    by_bucket: dict[tuple, int] = {}
    for plan in plans:
        key = tuple(plan.meta["bucket_shape"])
        by_bucket[key] = by_bucket.get(key, 0) + 1
    for plan in plans:
        assert plan.meta["bucket_size"] == by_bucket[tuple(plan.meta["bucket_shape"])]


def test_buckets_solve_at_member_max_not_quantized_ceiling(fleet_and_plans):
    """The quantized key only groups; the solve shape is the members' max
    extent — a homogeneous bucket pays zero padding."""
    probs, plans = fleet_and_plans
    shapes = {(p.n_jobs, p.n_slots): plan.meta["bucket_shape"]
              for p, plan in zip(probs, plans)}
    assert shapes[(5, 288)] == (8, 288)    # grouped with the 8x288 member
    assert shapes[(8, 240)] == (8, 244)    # grouped with 6x244, NOT (8, 256)
    assert shapes[(6, 244)] == (8, 244)
    assert shapes[(3, 192)] == (3, 192)    # singleton: exact shape


def test_homogeneous_fleet_pays_zero_padding():
    traces = trace.make_trace_set(PATH, hours=72, seed=0)
    probs = [lints.build(problem.paper_workload(n_jobs=6, seed=s),
                         traces, 0.5) for s in range(3)]
    plans = api.get_policy("lints_pdhg", config=CFG).plan_batch(probs)
    for plan in plans:
        assert plan.meta["bucket_shape"] == (6, 288)
        assert plan.meta["padded_jobs"] == 0
        assert plan.meta["padded_slots"] == 0
        assert plan.meta["bucket_size"] == 3


def test_padded_jobs_carry_zero_rate_everywhere():
    """Solve a padded bucket directly and check the padded region of the
    returned plans is EXACTLY zero (the invariant _unpad_plan asserts)."""
    p = _mixed_fleet()[2]                       # (3, 192) -> pad to (4, 224)
    padded = ragged.pad_problem(p, 4, 224)
    plans = lints._solve_batch_same_shape([padded], CFG, prechecked=True)
    rho = plans[0].rho_bps
    assert rho.shape == (4, 224)
    assert np.abs(rho[3:, :]).max() == 0.0
    assert np.abs(rho[:, 192:]).max() == 0.0
    # and the real block is a feasible plan for the original problem
    assert check_plan(p, rho[:3, :192], rel_tol=1e-5).feasible


def test_unpad_plan_raises_on_violated_invariant():
    from repro.core.plan import Plan

    p = _mixed_fleet()[2]                       # (3, 192)
    rho = np.zeros((4, 224))
    rho[3, 0] = 1.0                             # rate on a padded job
    with pytest.raises(RuntimeError, match="padding invariant"):
        ragged._unpad_plan(p, Plan(rho, "lints"), fleet_index=0,
                           fleet_size=1, bucket=(4, 224), bucket_size=1)


# -------------------------------------------------------------------- edges

def test_empty_fleet():
    assert ragged.solve_batch_ragged([], CFG) == []
    assert api.get_policy("lints_pdhg").plan_batch([]) == []


def test_infeasible_member_reports_fleet_index():
    probs = _mixed_fleet()[:2]
    traces = trace.make_trace_set(("US-NM",), hours=72, seed=0)
    bad = lints.build(
        [problem.TransferRequest(size_gb=1e6, deadline_slots=4,
                                 path=("US-NM",), request_id="huge")],
        traces, capacity_gbps=0.25)
    with pytest.raises(InfeasibleError, match="workload 2 infeasible"):
        ragged.solve_batch_ragged(probs + [bad], CFG)


def test_ragged_rejects_scipy_backend():
    with pytest.raises(ValueError, match="pdhg"):
        ragged.solve_batch_ragged(_mixed_fleet()[:1],
                                  lints.LinTSConfig(backend="scipy"))


def test_scipy_policy_plan_batch_loops_mixed_shapes():
    """The scipy backend accepts ragged fleets too (per-problem loop)."""
    probs = _mixed_fleet()[:2]
    plans = api.get_policy("lints").plan_batch(probs)
    for i, (p, plan) in enumerate(zip(probs, plans)):
        assert plan.rho_bps.shape == (p.n_jobs, p.n_slots)
        assert plan.meta["batch_index"] == i
        assert check_plan(p, plan.rho_bps, rel_tol=1e-5).feasible

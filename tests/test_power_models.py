"""Eqs. 1-7: throughput/power model invariants (unit + property tests)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep: skip module cleanly when absent
from hypothesis import given, settings, strategies as st

from repro.core.power import DEFAULT_POWER_MODEL, PowerModel


@given(
    theta=st.floats(0.1, 32.0),
    l_gbps=st.floats(0.05, 10.0),
)
@settings(max_examples=200, deadline=None)
def test_eq4_inverts_eq1(theta, l_gbps):
    pm = DEFAULT_POWER_MODEL
    rho = pm.throughput_gbps(theta, l_gbps)
    back = pm.threads(np.float64(rho), l_gbps, clip=False)
    assert back == pytest.approx(theta, rel=1e-6)


@given(l_gbps=st.floats(0.05, 5.0))
@settings(max_examples=50, deadline=None)
def test_throughput_monotone_and_bounded(l_gbps):
    pm = DEFAULT_POWER_MODEL
    thetas = np.linspace(0.0, 64.0, 100)
    rho = np.asarray(pm.throughput_gbps(thetas, l_gbps))
    assert np.all(np.diff(rho) >= -1e-12)
    assert np.all(rho <= l_gbps + 1e-12)
    assert rho[0] == pytest.approx(0.0)


def test_power_monotone_in_threads_and_zero_when_idle():
    pm = DEFAULT_POWER_MODEL
    thetas = np.linspace(0.0, 32.0, 50)
    p = np.asarray(pm.power_w(thetas))
    assert p[0] == 0.0  # idle slots consume nothing (paper §III-C)
    assert np.all(p[1:] >= pm.p_min_w - 1e-9)
    assert np.all(np.diff(p[1:]) >= -1e-9)
    assert p[-1] <= pm.p_max_w + 1e-9
    # Paper's own operating point: theta=32 draws ~98.6 W.
    assert float(pm.power_w(np.float64(32.0))) == pytest.approx(98.62, abs=0.05)


def test_linearization_matches_exact_at_endpoints():
    pm = DEFAULT_POWER_MODEL
    l = 0.5
    for rho in (1e-9, l - 1e-9):
        exact = float(pm.power_of_rho_exact_w(np.float64(rho), l))
        lin = float(pm.power_of_rho_linear_w(np.float64(rho), l))
        assert lin == pytest.approx(exact, abs=0.5)


@given(l_gbps=st.floats(0.1, 2.0), frac=st.floats(0.01, 0.99))
@settings(max_examples=100, deadline=None)
def test_linearization_error_bounded_by_delta_p(l_gbps, frac):
    pm = DEFAULT_POWER_MODEL
    rho = np.float64(frac * l_gbps)
    exact = float(pm.power_of_rho_exact_w(rho, l_gbps))
    lin = float(pm.power_of_rho_linear_w(rho, l_gbps))
    assert abs(exact - lin) <= pm.delta_p_w + 1e-6


def test_rate_cap_below_limit():
    pm = DEFAULT_POWER_MODEL
    for l in (0.25, 0.5, 0.75, 1.0):
        cap = pm.rate_cap_gbps(l)
        assert 0.0 < cap < l
        # threads at the cap equal theta_max exactly
        assert pm.threads(np.float64(cap), l, clip=False) == pytest.approx(
            pm.theta_max, rel=1e-6
        )


def test_custom_model_fields():
    pm = PowerModel(p_max_w=120.0, p_min_w=90.0)
    assert pm.delta_p_w == 30.0

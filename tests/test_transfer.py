"""Transfer manager: LinTS-in-the-loop replication, SLAs, drift replanning."""

import numpy as np
import pytest

from repro.core import lints
from repro.core.trace import make_trace_set
from repro.transfer import (
    CheckpointReplicator,
    Datacenter,
    Topology,
    TransferManager,
)

ZONES = ("US-NM", "US-WY", "US-SC")


def _manager(**kw):
    traces = make_trace_set(ZONES, hours=72, seed=0)
    topo = Topology(
        datacenters=(Datacenter("a", "US-NM"), Datacenter("b", "US-SC")),
        routes={("a", "b"): ZONES, ("b", "a"): ZONES[::-1]},
    )
    return TransferManager(topo, traces, capacity_gbps=1.0,
                           config=lints.LinTSConfig(backend="scipy"), **kw)


def test_transfer_completes_before_deadline():
    tm = _manager()
    rid = tm.enqueue(size_gb=40.0, src="a", dst="b", deadline_slots=96)
    tm.run_until_idle()
    t = tm.transfers[rid]
    assert t.done_slot is not None and t.done_slot < 96
    assert not t.violated
    rep = tm.report()
    assert rep["sla_violations"] == 0
    assert rep["total_emissions_kg"] > 0


def test_scheduler_prefers_low_carbon_slots():
    tm = _manager()
    rid = tm.enqueue(size_gb=10.0, src="a", dst="b", deadline_slots=288)
    tm.replan()
    rho = tm._plan_rho[rid]
    used = rho > 0
    assert used.any()
    path_ci = tm.forecast.path_intensity(ZONES)
    mean_used = path_ci[used].mean()
    assert mean_used < path_ci.mean()  # picked greener-than-average slots


def test_congestion_triggers_replan_and_still_completes():
    tm = _manager(replan_on_drift=True)
    tm.enqueue(size_gb=30.0, src="a", dst="b", deadline_slots=200)
    # 50% congestion for the first 40 slots.
    tm.run_until_idle(congestion_fn=lambda s: 0.5 if s < 40 else 1.0)
    rep = tm.report()
    assert rep["pending"] == 0
    assert rep["sla_violations"] == 0


def test_impossible_deadline_flags_sla():
    tm = _manager(replan_on_drift=False)
    tm.enqueue(size_gb=30.0, src="a", dst="b", deadline_slots=40)
    # Heavy congestion the whole window: the plan cannot deliver.
    tm.run_until_idle(max_slots=60, congestion_fn=lambda s: 0.05)
    assert tm.report()["sla_violations"] >= 1


def test_multiple_transfers_share_capacity():
    tm = _manager()
    for i in range(5):
        tm.enqueue(size_gb=20.0, src="a", dst="b", deadline_slots=96)
    tm.replan()
    total = np.zeros(tm.forecast.n_slots)
    for rho in tm._plan_rho.values():
        total += rho
    assert total.max() <= tm.capacity_gbps * 1e9 * (1 + 1e-9)
    tm.run_until_idle()
    assert tm.report()["sla_violations"] == 0


def test_checkpoint_replicator_hook(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint import CheckpointManager

    tm = _manager()
    mgr = CheckpointManager(str(tmp_path))
    mgr.on_commit = CheckpointReplicator(tm, "a", ["b"], deadline_slots=96)
    mgr.save(1, {"w": jnp.ones((1024,), jnp.float32)})
    assert len(tm.pending()) == 1
    t = tm.pending()[0]
    assert t.request_id.startswith("ckpt-00000001")
    assert t.size_gb > 0
    tm.run_until_idle()
    assert tm.report()["sla_violations"] == 0


def test_capacity_freed_after_transfer_completes():
    """Regression: ``capacity_bps_free`` summed planned rho over ALL
    ``_plan_rho`` entries, including completed transfers, so best-effort
    tail completion saw phantom reserved capacity."""
    tm = _manager()
    rid = tm.enqueue(size_gb=10.0, src="a", dst="b", deadline_slots=96)
    tm.replan()
    planned_slots = np.flatnonzero(tm._plan_rho[rid])
    assert planned_slots.size
    j = int(planned_slots[-1])
    full = tm.capacity_gbps * 1e9
    assert tm.capacity_bps_free(j) < full       # pending: plan reserves
    t = tm.transfers[rid]
    # Finished *before* slot j: the stale plan tail is phantom capacity.
    t.done_slot = j - 1
    assert tm.capacity_bps_free(j) == full
    # Finished *in* slot j: it moved bits on the link in j, so its
    # reservation still throttles same-slot best-effort traffic.
    t.done_slot = j
    assert tm.capacity_bps_free(j) < full


def test_best_effort_tail_shares_capacity():
    """Regression: each best-effort tail completion was granted
    ``capacity_bps_free`` without accounting for bits already taken by
    earlier best-effort transfers in the same slot, so two tail
    completions could jointly exceed link capacity."""
    tm = _manager(replan_on_drift=False)
    rids = [tm.enqueue(size_gb=100.0, src="a", dst="b", deadline_slots=96)
            for _ in range(2)]
    # Drop the plan entirely: both transfers run best-effort this slot.
    tm._needs_plan = False
    tm._plan_rho = {}
    tm._plan_matrix = None
    before = {r: tm.transfers[r].remaining_bits for r in rids}
    tm.tick()
    moved = sum(before[r] - tm.transfers[r].remaining_bits for r in rids)
    cap_bits = tm.capacity_gbps * 1e9 * tm.forecast.slot_seconds
    assert moved <= cap_bits * (1 + 1e-9)
    # Sharing, not starvation: the second transfer got the leftover.
    assert all(before[r] > tm.transfers[r].remaining_bits for r in rids)


def test_actual_path_intensity_cached():
    tm = _manager()
    ci1 = tm._actual_path_intensity(ZONES)
    ci2 = tm._actual_path_intensity(ZONES)
    assert ci1 is ci2  # frozen traces: combined once, reused every tick
    np.testing.assert_allclose(ci1, tm.actual.path_intensity(ZONES))


def test_unknown_route_raises():
    tm = _manager()
    with pytest.raises(KeyError):
        tm.enqueue(1.0, "a", "nowhere", 96)


# ------------------------------------------------------- pluggable policies

def test_policy_defaults_to_lints():
    tm = _manager()
    assert tm.policy.name == "lints"
    # back-compat: the config kwarg reconfigures the LinTS policy
    assert tm.config.backend == "scipy"
    assert tm.policy.config.backend == "scipy"


def test_policy_accepts_registry_name_and_instance():
    from repro.core import api

    traces = make_trace_set(ZONES, hours=72, seed=0)
    topo = Topology(
        datacenters=(Datacenter("a", "US-NM"), Datacenter("b", "US-SC")),
        routes={("a", "b"): ZONES, ("b", "a"): ZONES[::-1]},
    )
    tm = TransferManager(topo, traces, policy="edf")
    assert tm.policy.name == "edf"
    assert tm.report()["policy"] == "edf"
    pol = api.get_policy("fcfs", best_effort=True)
    tm2 = TransferManager(topo, traces, policy=pol)
    assert tm2.policy is pol
    assert tm2.config is None


def test_heuristic_name_resolves_best_effort_and_records_sla():
    """Regression: a strict heuristic used to escape tick() as an uncaught
    InfeasibleError on arrival-order-infeasible workloads.  Registry names
    now resolve to best-effort in the engine (which owns SLA accounting);
    an explicit Policy instance keeps strict semantics."""
    from repro.core import api

    traces = make_trace_set(ZONES, hours=72, seed=0)
    topo = Topology(
        datacenters=(Datacenter("a", "US-NM"), Datacenter("b", "US-SC")),
        routes={("a", "b"): ZONES, ("b", "a"): ZONES[::-1]},
    )
    tm = TransferManager(topo, traces, capacity_gbps=0.25, policy="fcfs")
    assert tm.policy.best_effort
    for i in range(10):
        tm.enqueue(size_gb=40.0, src="a", dst="b", deadline_slots=15)
    tm.run_until_idle(max_slots=30)          # must not raise
    rep = tm.report()
    assert rep["sla_violations"] >= 1        # misses are accounted, not fatal
    # explicit instances are respected as configured
    tm2 = TransferManager(topo, traces, policy=api.get_policy("fcfs"))
    assert not tm2.policy.best_effort


def test_config_kwarg_rejected_for_non_lints_policy():
    """config= would be silently dead under a heuristic policy — the
    manager now rejects the combination instead of ignoring it."""
    traces = make_trace_set(ZONES, hours=72, seed=0)
    topo = Topology(
        datacenters=(Datacenter("a", "US-NM"), Datacenter("b", "US-SC")),
        routes={("a", "b"): ZONES, ("b", "a"): ZONES[::-1]},
    )
    with pytest.raises(ValueError, match="config= only applies to LinTS"):
        TransferManager(topo, traces, policy="edf",
                        config=lints.LinTSConfig())


@pytest.mark.parametrize("policy", ["edf", "fcfs"])
def test_baseline_policy_completes_congestion_scenario(policy):
    """The ISSUE 4 acceptance scenario: baselines run in the online engine
    with the same SLA accounting the hardwired path gave LinTS."""
    traces = make_trace_set(ZONES, hours=72, seed=0)
    topo = Topology(
        datacenters=(Datacenter("a", "US-NM"), Datacenter("b", "US-SC")),
        routes={("a", "b"): ZONES, ("b", "a"): ZONES[::-1]},
    )
    tm = TransferManager(topo, traces, capacity_gbps=1.0, policy=policy,
                         replan_on_drift=True)
    tm.enqueue(size_gb=30.0, src="a", dst="b", deadline_slots=200)
    tm.run_until_idle(congestion_fn=lambda s: 0.5 if s < 40 else 1.0)
    rep = tm.report()
    assert rep["policy"] == policy
    assert rep["pending"] == 0
    assert rep["completed"] == 1
    assert rep["sla_violations"] == 0
    assert rep["deadline_truncations"] == 0
    # same accounting keys as the LinTS path
    lints_rep = _manager(replan_on_drift=True).report()
    assert set(rep) == set(lints_rep)


def test_policy_plans_differ_between_lints_and_edf():
    """EDF fills earliest slots; LinTS picks low-carbon ones — the engine
    really is running the requested policy, not LinTS under an alias."""
    traces = make_trace_set(ZONES, hours=72, seed=0)
    topo = Topology(
        datacenters=(Datacenter("a", "US-NM"), Datacenter("b", "US-SC")),
        routes={("a", "b"): ZONES, ("b", "a"): ZONES[::-1]},
    )
    plans = {}
    for policy in ("lints", "edf"):
        tm = TransferManager(topo, traces, capacity_gbps=1.0, policy=policy)
        rid = tm.enqueue(size_gb=10.0, src="a", dst="b", deadline_slots=288)
        tm.replan()
        plans[policy] = tm._plan_rho[rid]
    edf_slots = np.flatnonzero(plans["edf"])
    assert edf_slots[0] == 0            # EDF starts immediately
    assert not np.array_equal(plans["lints"], plans["edf"])


# ------------------------------------------- outage recovery (DESIGN.md §12)

FAULT_ZONES = ("US-NM", "US-WY", "US-SD", "US-CO")
FAULT_PRIMARY = ("US-NM", "US-WY", "US-SD")
FAULT_ALTERNATE = ("US-NM", "US-CO", "US-SD")


def _fault_manager(faults=None, *, recovery=True, resilient=True):
    from repro.core.faults import FaultSchedule  # noqa: F401 (type of faults)

    traces = make_trace_set(FAULT_ZONES, hours=12, slot_seconds=900.0, seed=0)
    topo = Topology(
        datacenters=(Datacenter("a", "US-NM"), Datacenter("b", "US-SD")),
        routes={("a", "b"): FAULT_PRIMARY},
        alternates={("a", "b"): (FAULT_ALTERNATE,)},
    )
    return TransferManager(topo, traces, capacity_gbps=1.0,
                           config=lints.LinTSConfig(backend="scipy"),
                           faults=faults, recovery=recovery,
                           resilient=resilient)


def _outage_at_half_progress():
    """Outage on the primary link from the clean plan's 50%-progress slot
    through the end of the horizon (the ISSUE 6 acceptance scenario)."""
    from repro.core.faults import FaultSchedule, LinkFault

    tm = _fault_manager()
    rid = tm.enqueue(size_gb=600.0, src="a", dst="b", deadline_slots=40)
    tm.replan()
    cum = np.cumsum(tm._plan_rho[rid]) * tm.forecast.slot_seconds
    half = int(np.searchsorted(cum, 0.5 * 600.0 * 8e9)) + 1
    return FaultSchedule(seed=7, link_faults=(
        LinkFault(("US-NM", "US-WY"), half, tm.forecast.n_slots,
                  factor=0.0),))


def test_midtransfer_outage_reroutes_and_meets_sla():
    """Primary link dies at ~50% progress; an alternate-path feasible
    schedule exists, so the engine must detect the outage, fail over and
    still meet the SLA."""
    fs = _outage_at_half_progress()
    tm = _fault_manager(fs)
    rid = tm.enqueue(size_gb=600.0, src="a", dst="b", deadline_slots=40)
    tm.run_until_idle()
    t = tm.transfers[rid]
    rep = tm.report()
    assert t.path == FAULT_ALTERNATE          # failed over
    assert t.reroutes >= 1 and rep["reroutes"] >= 1
    assert t.done_slot is not None and not t.violated
    assert rep["sla_violations"] == 0


def test_midtransfer_outage_without_recovery_records_miss():
    """Ladder/recovery disabled: the same outage must be *recorded* as an
    SLA miss, not silently absorbed."""
    fs = _outage_at_half_progress()
    tm = _fault_manager(fs, recovery=False, resilient=False)
    rid = tm.enqueue(size_gb=600.0, src="a", dst="b", deadline_slots=40)
    tm.run_until_idle()
    t = tm.transfers[rid]
    assert t.path == FAULT_PRIMARY            # never moved
    assert t.violated
    assert tm.report()["sla_violations"] >= 1
    assert tm.report()["reroutes"] == 0


def test_alternate_path_failover_probes_then_stays():
    """With BOTH candidate paths down: the monitor has no out-of-band
    signal, so the engine fails over to the (unprobed, presumed-healthy)
    alternate, discovers it dead through observations, and then stays put
    — exactly one reroute, and the loss is recorded, not hidden."""
    from repro.core.faults import FaultSchedule, LinkFault

    n_slots = 48
    both = FaultSchedule(seed=9, link_faults=(
        LinkFault(("US-NM", "US-WY"), 0, n_slots, factor=0.0),
        LinkFault(("US-NM", "US-CO"), 0, n_slots, factor=0.0),
    ))
    tm = _fault_manager(both)
    rid = tm.enqueue(size_gb=100.0, src="a", dst="b", deadline_slots=20)
    tm.run_until_idle(max_slots=25)
    t = tm.transfers[rid]
    assert t.path == FAULT_ALTERNATE          # probed the alternate...
    assert t.reroutes == 1                    # ...and had nowhere else to go
    assert t.violated                         # loss is recorded, not hidden


def test_replan_on_drift_disabled_skips_recovery_replans():
    """replan_on_drift=False keeps the engine static even under recovery:
    reroutes may mark the transfer but no replan reshapes the plan."""
    fs = _outage_at_half_progress()
    tm = _fault_manager(fs)
    tm.replan_on_drift = False
    rid = tm.enqueue(size_gb=600.0, src="a", dst="b", deadline_slots=40)
    tm.run_until_idle()
    # Without replanning the rerouted path never gets a schedule, so the
    # transfer can only finish via the best-effort tail — either way the
    # engine must not crash and accounting must stay consistent.
    t = tm.transfers[rid]
    assert (t.done_slot is not None) or t.violated


# ------------------------------------------------- deadline truncation (SLA)

def test_enqueue_records_deadline_truncation():
    tm = _manager()
    n_slots = tm.forecast.n_slots
    rid = tm.enqueue(size_gb=5.0, src="a", dst="b",
                     deadline_slots=n_slots + 40)
    t = tm.transfers[rid]
    assert t.deadline_slot == n_slots
    assert t.deadline_truncated_slots == 40
    assert tm.report()["deadline_truncations"] == 1
    # an in-horizon request records no truncation
    rid2 = tm.enqueue(size_gb=5.0, src="a", dst="b", deadline_slots=96)
    assert tm.transfers[rid2].deadline_truncated_slots == 0
    assert tm.report()["deadline_truncations"] == 1

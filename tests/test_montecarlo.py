"""Batched Monte-Carlo ensemble evaluation: parity with the single-draw
simulator path (per-draw reproducibility contract) and with the batched
Pallas kernel (DESIGN.md §8)."""

import numpy as np
import pytest

from repro.core import heuristics, montecarlo
from repro.core.problem import TransferRequest, paper_workload
from repro.core.simulator import evaluate_ensemble, evaluate_plan, noisy_costs
from repro.core.trace import INTENSITY_FLOOR_GCO2_PER_KWH

SIGMA = 0.15
SEED = 11


@pytest.fixture(scope="module")
def paper_reqs():
    return paper_workload(n_jobs=24, seed=3)


def test_zone_noise_draws_match_with_noise(paper_traces):
    """Draw d consumes exactly the stream of with_noise(sigma, seed + d)."""
    zones, noisy = montecarlo.zone_noise_draws(paper_traces, SIGMA, 3, SEED)
    assert noisy.shape == (3, len(zones), paper_traces.n_slots)
    for d in range(3):
        legacy = paper_traces.with_noise(SIGMA, SEED + d)
        for k, z in enumerate(zones):
            np.testing.assert_array_equal(noisy[d, k], legacy.zone_slots[z])


def test_zone_noise_seed_stream_contract_property():
    """Property sweep pinning the seed-stream contract (trace.py): for ANY
    (sigma, K, seed) and any trace set, ``zone_noise_draws`` draw ``d``
    consumes exactly the stream of ``TraceSet.with_noise(sigma, seed + d)``.
    The scenario-robust planner (``build_robust_problem``) leans on this to
    keep planning draws and evaluation draws on one addressable stream —
    randomized here (seeded, no hypothesis dep) rather than example-based."""
    from repro.core.trace import TraceSet

    rng = np.random.default_rng(99)
    for _ in range(20):
        n_zones = int(rng.integers(1, 5))
        n_slots = int(rng.integers(4, 64))
        traces = TraceSet(
            slot_seconds=900.0,
            zone_slots={
                f"Z{z}": np.clip(rng.normal(400, 150, size=n_slots),
                                 20.0, None)
                for z in range(n_zones)
            },
        )
        sigma = float(rng.uniform(0.01, 1.0))
        k = int(rng.integers(1, 9))
        seed = int(rng.integers(0, 2**31))
        zones, noisy = montecarlo.zone_noise_draws(traces, sigma, k, seed)
        assert list(zones) == list(traces.zone_slots)
        for d in range(k):
            legacy = traces.with_noise(sigma, seed + d)
            for i, z in enumerate(zones):
                np.testing.assert_array_equal(noisy[d, i],
                                              legacy.zone_slots[z])


def test_draw_noisy_costs_match_noisy_costs_loop(paper_traces, paper_reqs):
    draws = montecarlo.draw_noisy_costs(paper_reqs, paper_traces, SIGMA, 4,
                                        SEED)
    assert draws.shape == (4, len(paper_reqs), paper_traces.n_slots)
    for d in range(4):
        legacy = noisy_costs(paper_reqs, paper_traces, SIGMA, seed=SEED + d)
        np.testing.assert_allclose(draws[d], legacy, rtol=1e-12)


def test_noise_respects_intensity_floor(paper_traces):
    _, noisy = montecarlo.zone_noise_draws(paper_traces, 5.0, 8, SEED)
    assert noisy.min() >= INTENSITY_FLOOR_GCO2_PER_KWH
    huge = paper_traces.with_noise(5.0, SEED)
    assert min(t.min() for t in huge.zone_slots.values()) \
        >= INTENSITY_FLOOR_GCO2_PER_KWH


def test_path_weight_matrix_honors_weights_and_repeats(paper_traces):
    zones = list(paper_traces.zone_slots)
    reqs = [
        TransferRequest(size_gb=1.0, deadline_slots=8,
                        path=(zones[0], zones[1], zones[0]),
                        weights=(0.5, 1.0, 2.0), request_id="r0"),
    ]
    w = montecarlo.path_weight_matrix(reqs, zones)
    assert w[0, 0] == pytest.approx(2.5)   # 0.5 + 2.0 (repeated zone)
    assert w[0, 1] == pytest.approx(1.0)
    draws = montecarlo.draw_noisy_costs(reqs, paper_traces, SIGMA, 2, SEED)
    legacy = noisy_costs(reqs, paper_traces, SIGMA, seed=SEED)
    np.testing.assert_allclose(draws[0], legacy, rtol=1e-12)


def test_evaluate_ensemble_parity_with_evaluate_plan_loop(small_problem,
                                                          paper_traces,
                                                          paper_reqs):
    """Acceptance: ensemble totals match a python loop of evaluate_plan
    over the same noisy draws to <=1e-6 relative error."""
    plans = [heuristics.edf(small_problem), heuristics.fcfs(small_problem),
             heuristics.single_threshold(small_problem)]
    n_draws = 16
    draws = montecarlo.draw_noisy_costs(paper_reqs, paper_traces, SIGMA,
                                        n_draws, SEED)
    ens = evaluate_ensemble(small_problem, plans, SIGMA, n_draws,
                            requests=paper_reqs, traces=paper_traces,
                            seed=SEED)
    for plan in plans:
        rep = ens[plan.algorithm]
        assert rep.n_draws == n_draws
        for d in range(n_draws):
            want = evaluate_plan(small_problem, plan, draws[d])
            got = rep.total_gco2[d]
            assert abs(got - want.total_gco2) <= 1e-6 * want.total_gco2
        base = evaluate_plan(small_problem, plan)
        assert rep.sla_violations == base.sla_violations
        assert rep.active_job_slots == base.active_job_slots
        assert rep.energy_kwh == pytest.approx(base.energy_kwh, rel=1e-12)


def test_ensemble_statistics_consistent(small_problem, paper_traces,
                                        paper_reqs):
    ens = evaluate_ensemble(small_problem, [heuristics.edf(small_problem)],
                            SIGMA, 32, requests=paper_reqs,
                            traces=paper_traces, seed=SEED)
    rep = ens["edf"]
    assert rep.mean_gco2 == pytest.approx(rep.total_gco2.mean(), rel=1e-12)
    assert rep.std_gco2 == pytest.approx(np.std(rep.total_gco2, ddof=1),
                                         rel=1e-12)
    assert rep.ci95_gco2 == pytest.approx(1.96 * rep.std_gco2 / np.sqrt(32),
                                          rel=1e-12)
    assert rep.per_job_gco2.sum() == pytest.approx(rep.mean_gco2, rel=1e-9)
    assert rep.per_slot_gco2.sum() == pytest.approx(rep.mean_gco2, rel=1e-9)
    assert rep.mean_kg == pytest.approx(rep.mean_gco2 / 1000.0)


def test_evaluate_ensemble_requires_noise_source(small_problem):
    with pytest.raises(ValueError, match="requests"):
        evaluate_ensemble(small_problem, [heuristics.edf(small_problem)],
                          SIGMA, 4)


def test_batched_gco2_kernel_parity(small_problem, paper_traces, paper_reqs):
    """Interpret-mode Pallas kernel vs the float64 numpy pass."""
    plans = [heuristics.edf(small_problem), heuristics.fcfs(small_problem)]
    rho = np.stack([p.rho_bps for p in plans])
    draws = montecarlo.draw_noisy_costs(paper_reqs, paper_traces, SIGMA, 3,
                                        SEED)
    job_np, slot_np = montecarlo.batched_gco2(small_problem, rho, draws,
                                              use_kernel=False)
    job_k, slot_k = montecarlo.batched_gco2(small_problem, rho, draws,
                                            use_kernel=True)
    np.testing.assert_allclose(job_k, job_np, rtol=2e-5,
                               atol=1e-5 * job_np.max())
    np.testing.assert_allclose(slot_k, slot_np, rtol=2e-5,
                               atol=1e-5 * slot_np.max())


def test_emissions_totals_defaults_to_forecast(small_problem):
    plan = heuristics.edf(small_problem)
    totals = montecarlo.emissions_totals(small_problem, plan.rho_bps[None])
    assert totals.shape == (1, 1)
    want = evaluate_plan(small_problem, plan).total_gco2
    assert totals[0, 0] == pytest.approx(want, rel=1e-9)

"""Sharding rules: divisibility fallbacks, spec ranks, opt-state mirroring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import OptimizerConfig, TrainConfig, registry
from repro.distributed import sharding as shd
from repro.models import lm
from repro.optim import adamw
from repro.train import abstract_state


class FakeMesh:
    """Shape-only stand-in (rule logic never touches devices)."""

    def __init__(self, shape: dict):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH_256 = FakeMesh({"data": 16, "model": 16})
MESH_512 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _shapes(arch, reduced=False):
    cfg = registry.get(arch).model(reduced=reduced)
    return cfg, jax.eval_shape(
        lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0)
    )


@pytest.mark.parametrize("arch", registry.list_archs())
def test_param_specs_rank_and_divisibility(arch):
    cfg, shapes = _shapes(arch)
    specs = shd.param_specs(shapes, MESH_256)

    def check(path, leaf, spec):
        assert len(tuple(spec)) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, axis in zip(leaf.shape, tuple(spec)):
            if axis is None:
                continue
            size = (np.prod([MESH_256.shape[a] for a in axis])
                    if isinstance(axis, tuple) else MESH_256.shape[axis])
            assert dim % size == 0, (path, spec, leaf.shape)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(shd.path_str(p), l, s), shapes, specs,
    )


def test_big_weights_are_sharded_not_replicated():
    _, shapes = _shapes("qwen2.5-14b")
    specs = shd.param_specs(shapes, MESH_256)
    flat = {
        shd.path_str(p): s
        for p, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]
    }
    assert flat["embed/table"] == P("model", "data")
    assert flat["stage_0/blocks/0/attn/wq"] == P(None, "data", "model")
    assert flat["stage_0/blocks/0/mlp/w_down"] == P(None, "model", "data")


def test_mamba_vocab_fallback_replicates():
    """mamba2's vocab (50280) doesn't divide 16 -> dim must be replicated."""
    _, shapes = _shapes("mamba2-130m")
    specs = shd.param_specs(shapes, MESH_256)
    emb = specs["embed"]["table"]
    assert tuple(emb)[0] is None  # vocab dim dropped
    # w_in output (3352) not divisible by 16 either.
    win = specs["stage_0"]["blocks"]["0"]["mixer"]["w_in"]
    assert tuple(win) == (None, "data", None)


def test_moe_experts_sharded_over_model():
    _, shapes = _shapes("llama4-maverick-400b-a17b")
    specs = shd.param_specs(shapes, MESH_256)
    wg = specs["stage_0"]["blocks"]["1"]["moe"]["w_gate"]
    assert tuple(wg) == (None, "model", "data", None)


def test_cache_spec_fallbacks():
    from repro.configs.base import AttentionConfig

    # kv heads = 8 cannot split 16-way TP -> falls back to length sharding.
    shapes = {
        "kv": {
            "k": jax.ShapeDtypeStruct((4, 128, 32768, 8, 128), jnp.bfloat16),
            "v": jax.ShapeDtypeStruct((4, 128, 32768, 8, 128), jnp.bfloat16),
        }
    }
    specs = shd.cache_specs(shapes, MESH_256, batched=True)
    assert tuple(specs["kv"]["k"]) == (None, "data", "model", None, None)
    # kv heads = 16 shards heads directly.
    shapes16 = {
        "kv": {"k": jax.ShapeDtypeStruct((4, 128, 32768, 16, 128), jnp.bfloat16)}
    }
    specs16 = shd.cache_specs(shapes16, MESH_256, batched=True)
    assert tuple(specs16["kv"]["k"]) == (None, "data", None, "model", None)


def test_opt_specs_mirror_params():
    cfg = registry.get("internlm2-1.8b").model(reduced=True)
    tcfg = TrainConfig(global_batch=2, seq_len=16,
                       optimizer=OptimizerConfig(name="adamw8bit"))
    shapes = abstract_state(jax.random.PRNGKey(0), cfg, tcfg)
    p_specs = shd.param_specs(shapes["params"], MESH_256)
    o_specs = shd.opt_specs(shapes["opt"], p_specs, MESH_256)
    some_param_spec = p_specs["stage_0"]["blocks"]["0"]["attn"]["wq"]
    mom = o_specs["moments"]["stage_0"]["blocks"]["0"]["attn"]["wq"]
    assert mom["m_q"] == some_param_spec
    assert tuple(mom["m_s"])[-1] is None
    assert o_specs["count"] == P()


def test_batch_axis_includes_pod():
    amap = shd.axis_map(MESH_512)
    assert amap["batch"] == ("pod", "data")
    amap1 = shd.axis_map(MESH_256)
    assert amap1["batch"] == "data"

"""Chunked VMEM-resident PDHG window kernels vs the jnp oracle.

Parity contract: after a full restart window (K fused iterations) the
kernel's carry — current iterate, duals, x_bar row/col sums, and the
running-sum accumulators — matches ``ref.pdhg_window_ref`` (which delegates
to the solver's own ``use_kernel=False`` loop, so the oracle cannot drift
from the solver).  All kernels run in interpret mode on CPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core.pdhg import PDHGConfig, pdhg_solve, pdhg_solve_batch, solve_pdhg
from repro.core.scipy_backend import solve_scipy
from repro.kernels import ops, ref
from repro.kernels.pdhg_window import (
    fused_window_fits,
    pdhg_window_fused_pallas,
    pdhg_window_tiled_pallas,
)

# Odd / non-block-multiple shapes on purpose: the wrappers pad to
# layout-native multiples and padding must be value-neutral.
SHAPES = [(3, 7), (24, 96), (50, 288), (129, 257), (200, 288)]
WINDOW = 120


def _mk_window_state(rng, n, m):
    ub = jnp.asarray((rng.uniform(0, 1, (n, m)) > 0.3).astype(np.float32))
    x = jnp.asarray(rng.uniform(0, 1, (n, m)).astype(np.float32)) * ub
    c = jnp.asarray(rng.uniform(0, 3, (n, m)).astype(np.float32)) * ub
    u = jnp.asarray(rng.uniform(0, 2, n).astype(np.float32))
    v = jnp.asarray(rng.uniform(0, 2, m).astype(np.float32))
    rs = x.sum(axis=1)
    cs = x.sum(axis=0)
    b_row = jnp.asarray(rng.uniform(0.1, 2, n).astype(np.float32))
    b_col = jnp.float32(2.5)
    return x, c, ub, u, v, rs, cs, b_row, b_col


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_window_matches_oracle(shape):
    rng = np.random.default_rng(sum(shape))
    state = _mk_window_state(rng, *shape)
    want = ref.pdhg_window_ref(*state, 0.05, 0.04, WINDOW)
    got = pdhg_window_fused_pallas(*state, 0.05, 0.04, n_iters=WINDOW,
                                   interpret=True)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=5e-5, atol=5e-5)


@pytest.mark.parametrize("shape", [(24, 96), (129, 257), (200, 288)])
def test_tiled_window_matches_oracle(shape):
    """Row-tiled fallback: col-dual state carried across the grid."""
    rng = np.random.default_rng(sum(shape) + 1)
    state = _mk_window_state(rng, *shape)
    want = ref.pdhg_window_ref(*state, 0.05, 0.04, WINDOW)
    got = pdhg_window_tiled_pallas(*state, 0.05, 0.04, n_iters=WINDOW,
                                   block_r=8, interpret=True)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


def test_auto_select_tiled_under_tight_budget():
    """The dispatcher routes to the tiled kernel when the budget is tiny."""
    from repro.kernels import pdhg_window as W

    rng = np.random.default_rng(7)
    state = _mk_window_state(rng, 64, 256)
    budget = 64 * 1024  # force tiling: 64x256 f32 plane alone is 64 KiB
    assert not fused_window_fits(64, 256, 4, budget)
    got = W.pdhg_window(*state, 0.05, 0.04, n_iters=40, interpret=True,
                        vmem_budget_bytes=budget)
    want = ref.pdhg_window_ref(*state, 0.05, 0.04, 40)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


def test_batched_window_matches_vmapped_oracle():
    rng = np.random.default_rng(11)
    B, n, m = 3, 24, 96
    states = [_mk_window_state(rng, n, m) for _ in range(B)]
    stacked = [jnp.stack([s[k] for s in states]) for k in range(9)]
    tau = jnp.asarray([0.05, 0.04, 0.06], jnp.float32)
    sigma = jnp.asarray([0.04, 0.05, 0.03], jnp.float32)
    done = jnp.zeros((B,), bool)
    got = ops.pdhg_window_batched(*stacked, tau, sigma, done, n_iters=60,
                                  interpret=True)
    want = jax.vmap(lambda *a: ref.pdhg_window_ref(*a, 60))(*stacked, tau,
                                                            sigma)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=5e-5, atol=5e-5)


def test_batched_window_done_lane_passes_carry_through():
    """A converged LP's window is skipped: carry comes back bit-identical."""
    rng = np.random.default_rng(13)
    B, n, m = 3, 16, 64
    states = [_mk_window_state(rng, n, m) for _ in range(B)]
    stacked = [jnp.stack([s[k] for s in states]) for k in range(9)]
    tau = jnp.full((B,), 0.05, jnp.float32)
    sigma = jnp.full((B,), 0.04, jnp.float32)
    done = jnp.asarray([False, True, False])
    got = ops.pdhg_window_batched(*stacked, tau, sigma, done, n_iters=50,
                                  interpret=True)
    # lane 1 carry (x, u, v, rs, cs) is untouched
    carry_in = [stacked[k] for k in (0, 3, 4, 5, 6)]  # x, u, v, rs, cs
    for g, inp in zip(got[:5], carry_in):
        np.testing.assert_array_equal(np.asarray(g[1]), np.asarray(inp[1]))
    # active lanes still match the oracle
    want = jax.vmap(lambda *a: ref.pdhg_window_ref(*a, 50))(*stacked, tau,
                                                            sigma)
    for lane in (0, 2):
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g[lane]),
                                       np.asarray(w[lane]),
                                       rtol=5e-5, atol=5e-5)


def test_window_kernel_solve_matches_jnp_solve(small_problem):
    """Full solver: chunked-kernel path == jnp path on the same problem."""
    from repro.core.pdhg import normalize_problem

    c, ub, br, bc, _ = normalize_problem(small_problem)
    xj, dj = pdhg_solve(c, ub, br, bc, max_iters=4000, check_every=200,
                        use_kernel=False)
    xw, dw = pdhg_solve(c, ub, br, bc, max_iters=4000, check_every=200,
                        use_kernel=True, kernel_mode="window",
                        kernel_interpret=True)
    np.testing.assert_allclose(np.asarray(xw), np.asarray(xj),
                               rtol=1e-5, atol=1e-5)
    assert int(dj["iterations"]) == int(dw["iterations"])


def test_window_kernel_solver_reaches_scipy_objective(small_problem):
    """Regression: kernel-path PDHG lands on the HiGHS objective on the
    paper workload."""
    ref_plan = solve_scipy(small_problem)
    got = solve_pdhg(small_problem, PDHGConfig(
        max_iters=30_000, check_every=200, tol=2e-5,
        use_kernel=True, kernel_mode="window", kernel_interpret=True))
    assert got.meta["converged"]
    assert got.meta["objective"] <= ref_plan.meta["objective"] * 1.005 + 1e-9


def test_batched_solve_reports_per_problem_early_exit(small_problem):
    """Fleet solve: per-problem iteration counts match solo solves (each LP
    stops accruing iterations once converged, instead of running the
    fleet-wide max)."""
    from repro.core.pdhg import normalize_problem
    from repro.core import problem as prob_mod
    from repro.core import lints, trace

    traces = trace.make_trace_set(("US-NM", "US-WY", "US-SD"), hours=72,
                                  seed=0)
    probs = [lints.build(prob_mod.paper_workload(n_jobs=12, seed=s), traces,
                         0.5) for s in range(3)]
    tensors = [normalize_problem(p) for p in probs]
    stacked = [jnp.stack([t[k] for t in tensors]) for k in range(4)]
    xs, diag = pdhg_solve_batch(*stacked, max_iters=20_000, check_every=200,
                                use_kernel=False)
    assert diag["iterations"].shape == (3,)
    assert bool(diag["converged"].all())
    for i, (t, p) in enumerate(zip(tensors, probs)):
        _, solo = pdhg_solve(*t[:4], max_iters=20_000, check_every=200,
                             use_kernel=False)
        assert int(diag["iterations"][i]) == int(solo["iterations"])


def test_batched_solve_kernel_path_matches_jnp_path(small_problem):
    from repro.core.pdhg import normalize_problem
    from repro.core import problem as prob_mod
    from repro.core import lints, trace

    traces = trace.make_trace_set(("US-NM", "US-WY", "US-SD"), hours=72,
                                  seed=0)
    probs = [lints.build(prob_mod.paper_workload(n_jobs=10, seed=s), traces,
                         0.5) for s in range(2)]
    tensors = [normalize_problem(p) for p in probs]
    stacked = [jnp.stack([t[k] for t in tensors]) for k in range(4)]
    xj, dj = pdhg_solve_batch(*stacked, max_iters=8000, check_every=200,
                              use_kernel=False)
    xk, dk = pdhg_solve_batch(*stacked, max_iters=8000, check_every=200,
                              use_kernel=True, kernel_interpret=True)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xj),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(dk["iterations"]),
                                  np.asarray(dj["iterations"]))


def test_lints_solve_batch_fleet_api(small_problem):
    from repro.core import lints, problem as prob_mod, trace
    from repro.core.feasibility import check_plan

    traces = trace.make_trace_set(("US-NM", "US-WY", "US-SD"), hours=72,
                                  seed=0)
    probs = [lints.build(prob_mod.paper_workload(n_jobs=8, seed=s), traces,
                         0.5) for s in range(3)]
    cfg = lints.LinTSConfig(
        backend="pdhg",
        pdhg=PDHGConfig(max_iters=20_000, check_every=200, tol=2e-5,
                        use_kernel=False))
    plans = api.get_policy("lints_pdhg", config=cfg).plan_batch(probs)
    assert len(plans) == 3
    for p, plan in zip(probs, plans):
        assert check_plan(p, plan.rho_bps).feasible
        assert plan.meta["converged"]
        assert plan.meta["iterations"] > 0


def test_lints_solve_batch_rejects_infeasible_workload(small_problem):
    from repro.core import lints, trace
    from repro.core.problem import TransferRequest

    traces = trace.make_trace_set(("US-NM",), hours=72, seed=0)
    reqs = [TransferRequest(size_gb=1e6, deadline_slots=4,
                            path=("US-NM",), request_id="huge")]
    bad = lints.build(reqs, traces, capacity_gbps=0.25)
    with pytest.raises(lints.InfeasibleError, match="workload 0 infeasible"):
        api.get_policy("lints_pdhg").plan_batch([bad])


def test_lints_solve_batch_honors_refine(small_problem):
    from repro.core import lints, problem as prob_mod, trace
    from repro.core.simulator import evaluate_plan

    traces = trace.make_trace_set(("US-NM", "US-WY", "US-SD"), hours=72,
                                  seed=0)
    probs = [lints.build(prob_mod.paper_workload(n_jobs=8, seed=s), traces,
                         0.5) for s in range(2)]
    pd = PDHGConfig(max_iters=20_000, check_every=200, tol=2e-5,
                    use_kernel=False)
    base = api.get_policy("lints_pdhg", config=lints.LinTSConfig(
        backend="pdhg", pdhg=pd)).plan_batch(probs)
    refined = api.get_policy("lints_pdhg", config=lints.LinTSConfig(
        backend="pdhg", pdhg=pd, refine=True)).plan_batch(probs)
    for p, b, r in zip(probs, base, refined):
        assert r.algorithm == "lints+"
        assert (evaluate_plan(p, r).total_gco2
                <= evaluate_plan(p, b).total_gco2 + 1e-6)


def test_compiled_oversize_window_falls_back_to_step_kernel(monkeypatch):
    """Compiled (non-interpret) + over-budget => per-iteration cell kernel,
    not the interpret-only tiled window kernel (DESIGN.md §2)."""
    from repro.kernels import pdhg_window as W

    rng = np.random.default_rng(5)
    state = _mk_window_state(rng, 32, 128)
    budget = 16 * 1024  # force the over-budget branch

    called = {"tiled": False}
    monkeypatch.setattr(
        W, "pdhg_window_tiled_pallas",
        lambda *a, **k: called.__setitem__("tiled", True) or None)
    # interpret=True still uses the tiled kernel (stubbed here)
    W.pdhg_window(*state, 0.05, 0.04, n_iters=4, interpret=True,
                  vmem_budget_bytes=budget)
    assert called["tiled"]

    # interpret=False routes through the step-kernel window instead; run
    # the step kernel itself in interpret mode so this works on CPU.
    step_called = {"n": 0}
    real_step = W._window_via_step_kernel

    def spy(*a, **k):
        step_called["n"] += 1
        k["interpret"] = True
        return real_step(*a, **k)

    monkeypatch.setattr(W, "_window_via_step_kernel", spy)
    got = W.pdhg_window(*state, 0.05, 0.04, n_iters=4, interpret=False,
                        vmem_budget_bytes=budget)
    assert step_called["n"] == 1
    want = ref.pdhg_window_ref(*state, 0.05, 0.04, 4)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=5e-5, atol=5e-5)


def test_kernel_mode_validated():
    with pytest.raises(ValueError, match="unknown kernel_mode"):
        pdhg_solve(jnp.zeros((4, 8)), jnp.ones((4, 8)), jnp.ones((4,)),
                   jnp.float32(1.0), max_iters=100, check_every=50,
                   kernel_mode="wndow")

"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see the real (1-device) topology; only launch/dryrun.py forces
512 placeholder devices, in its own process."""

import numpy as np
import pytest

from repro.core import lints, problem, trace


@pytest.fixture(scope="session")
def paper_traces():
    return trace.make_trace_set(("US-NM", "US-WY", "US-SD"), hours=72, seed=0)


@pytest.fixture(scope="session")
def paper_requests():
    return problem.paper_workload(n_jobs=24, seed=3)


@pytest.fixture(scope="session")
def small_problem(paper_traces, paper_requests):
    return lints.build(paper_requests, paper_traces, capacity_gbps=0.5)


@pytest.fixture()
def saturated_problem():
    """2 jobs x 2 slots at exactly full link capacity, plus the matching
    half-half plan: every slot is saturated and no single slot can host
    either job's remainder, so LinTS+ refinement must take its
    keep-current fallback and return the plan unchanged."""
    traces = trace.TraceSet(slot_seconds=900.0,
                            zone_slots={"A": np.array([400.0, 300.0])})
    need_bits = 0.5e9 * 900.0          # == capacity_bps * slot_seconds
    reqs = [
        problem.TransferRequest(size_gb=need_bits / 8e9, deadline_slots=2,
                                path=("A",), request_id=f"r{i}")
        for i in range(2)
    ]
    prob = lints.build(reqs, traces, capacity_gbps=0.5)
    rho = np.full((2, 2), prob.capacity_bps / 2)
    return prob, rho


def random_problem(rng: np.random.Generator, n_jobs=None, n_slots=None,
                   capacity_gbps=None):
    """Random feasible-ish scheduling problem for property tests."""
    n_jobs = n_jobs or int(rng.integers(1, 12))
    n_slots = n_slots or int(rng.integers(16, 64))
    capacity_gbps = capacity_gbps or float(rng.uniform(0.2, 1.0))
    zones = ("US-NM", "US-WY", "US-SD")
    traces = trace.TraceSet(
        slot_seconds=900.0,
        zone_slots={
            z: np.clip(
                rng.normal(400, 150, size=n_slots), 20.0, None
            ) for z in zones
        },
    )
    # Keep total demand under ~50% of aggregate capacity for feasibility.
    budget_gb = 0.5 * capacity_gbps * 1e9 * 900.0 * n_slots / 8e9
    sizes = rng.uniform(0.2, max(0.4, budget_gb / n_jobs), size=n_jobs)
    reqs = []
    for i in range(n_jobs):
        deadline = int(rng.integers(max(2, n_slots // 2), n_slots + 1))
        offset = int(rng.integers(0, max(1, deadline - 2)))
        reqs.append(problem.TransferRequest(
            size_gb=float(sizes[i]), deadline_slots=deadline,
            offset_slots=offset, path=zones, request_id=f"r{i}",
        ))
    return lints.build(reqs, traces, capacity_gbps)

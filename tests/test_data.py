"""Data pipeline: determinism, resume, shard disjointness, memmap corpus."""

import numpy as np
import pytest

from repro.data import SyntheticTokens, TokenFile


def test_deterministic_across_instances():
    a = SyntheticTokens(100, 16, 4, seed=1)
    b = SyntheticTokens(100, 16, 4, seed=1)
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])


def test_resume_from_state():
    a = SyntheticTokens(100, 16, 4, seed=2)
    a.next_batch(); a.next_batch()
    state = a.get_state()
    want = a.next_batch()

    b = SyntheticTokens(100, 16, 4, seed=2)
    b.set_state(state)
    got = b.next_batch()
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_shards_disjoint_and_sized():
    shards = [
        SyntheticTokens(100, 16, 8, shard_index=i, shard_count=2, seed=3)
        for i in range(2)
    ]
    b0, b1 = shards[0].next_batch(), shards[1].next_batch()
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_shifted_by_one():
    src = SyntheticTokens(100, 16, 2, seed=4)
    b = src.next_batch()
    # labels[t] is the next token after tokens[t] within the same sequence:
    # verify via regenerating (tokens[1:] == labels[:-1]).
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure_learnable():
    """Consecutive-token mutual structure >> uniform (so training can show
    loss going down)."""
    src = SyntheticTokens(64, 256, 2, seed=5)
    b = src.next_batch()
    toks, labs = b["tokens"], b["labels"]
    diffs = (labs - toks) % 64
    # The shift alphabet has 64 values but transitions are deterministic
    # 90% of the time -> diff entropy must be far below log2(64).
    _, counts = np.unique(diffs, return_counts=True)
    p = counts / counts.sum()
    entropy = -(p * np.log2(p)).sum()
    assert entropy < 5.7


def test_token_file_roundtrip(tmp_path):
    path = str(tmp_path / "corpus.bin")
    data = np.arange(9 * 17, dtype=np.int32)
    TokenFile.write(path, data)
    tf = TokenFile(path, seq_len=16, global_batch=2)
    b = tf.next_batch()
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["tokens"][0], data[:16])
    np.testing.assert_array_equal(b["labels"][0], data[1:17])


def test_token_file_shards(tmp_path):
    path = str(tmp_path / "corpus.bin")
    TokenFile.write(path, np.arange(4 * 17, dtype=np.int32))
    s0 = TokenFile(path, 16, 2, shard_index=0, shard_count=2)
    s1 = TokenFile(path, 16, 2, shard_index=1, shard_count=2)
    b0, b1 = s0.next_batch(), s1.next_batch()
    assert not np.array_equal(b0["tokens"], b1["tokens"])

"""Fault tolerance: heartbeats, stragglers, elastic mesh planning."""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep: skip module cleanly when absent
from hypothesis import given, settings, strategies as st

from repro.runtime import FailureInjector, HeartbeatMonitor, plan_mesh


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_dead_worker_detection():
    clock = FakeClock()
    mon = HeartbeatMonitor(4, timeout_s=10.0, clock=clock)
    for w in range(4):
        mon.beat(w, 1.0)
    clock.t = 5.0
    for w in (0, 1, 2):
        mon.beat(w, 1.0)
    clock.t = 12.0
    assert mon.dead_workers() == [3]
    status = mon.status()
    assert not status[3].alive and status[0].alive


def test_straggler_detection():
    mon = HeartbeatMonitor(4, straggler_factor=2.0)
    for _ in range(8):
        for w in range(4):
            mon.beat(w, 1.0 if w != 2 else 3.5)
    assert mon.stragglers() == [2]
    assert mon.status()[2].is_straggler


def test_no_straggler_with_uniform_times():
    mon = HeartbeatMonitor(8)
    for _ in range(8):
        for w in range(8):
            mon.beat(w, 1.0 + 0.01 * w)
    assert mon.stragglers() == []


def test_failure_injector():
    inj = FailureInjector({10: ("kill", 3)})
    assert inj.at(10) == ("kill", 3)
    assert inj.at(11) is None


@given(n=st.integers(1, 4096))
@settings(max_examples=200, deadline=None)
def test_plan_mesh_properties(n):
    plan = plan_mesh(n, prefer_model=16)
    used = int(np.prod(plan.shape))
    assert used + plan.dropped_devices == n or used <= n
    assert used >= 1
    assert used <= n
    # model axis preserves preference when divisible
    model = plan.shape[-1]
    assert model in (1, 2, 4, 8, 16)
    if n % 16 == 0 and n >= 16:
        assert model == 16
    # multi-pod shape appears at >=512 devices
    if used >= 512:
        assert plan.axis_names[0] == "pod"


def test_plan_mesh_elastic_shrink():
    full = plan_mesh(512)
    assert full.shape == (2, 16, 16)
    degraded = plan_mesh(511)  # one node lost
    used = int(np.prod(degraded.shape))
    assert used == 256  # falls back to the largest clean power-of-two grid
    assert degraded.shape[-1] == 16


def test_build_local_mesh_and_reshard():
    """End-to-end elastic flow on the 1-device container."""
    from repro.configs import OptimizerConfig, TrainConfig, registry
    from repro.runtime import reshard_state
    from repro.train import abstract_state, init_state

    cfg = registry.get("internlm2-1.8b").model(reduced=True)
    tcfg = TrainConfig(global_batch=2, seq_len=16,
                       optimizer=OptimizerConfig(warmup_steps=1, total_steps=2))
    key = jax.random.PRNGKey(0)
    state = init_state(key, cfg, tcfg)
    host = jax.device_get(state)
    shapes = abstract_state(key, cfg, tcfg)
    new_mesh = plan_mesh(len(jax.devices())).build()
    placed = reshard_state(host, shapes, new_mesh)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(placed)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )

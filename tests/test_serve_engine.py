"""Serving engine: batching invariance + bucket-padded prefill correctness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.serve import ServingEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = registry.get("internlm2-1.8b").model(reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _manual_greedy(params, cfg, prompt, n_new):
    """Token-by-token reference using raw forward (no cache)."""
    toks = list(prompt)
    for _ in range(n_new):
        logits, _, _ = lm.forward(
            params, cfg, tokens=jnp.asarray([toks], jnp.int32)
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_manual_greedy(tiny):
    params, cfg = tiny
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=7).tolist()
    n_new = 6
    engine = ServingEngine(params, cfg, max_batch=2, max_len=64,
                           cache_dtype=jnp.float32)
    rid = engine.submit(prompt, max_new_tokens=n_new)
    out = engine.run()[rid]
    want = _manual_greedy(params, cfg, prompt, n_new)
    assert out == want


def test_batched_equals_solo(tiny):
    """Greedy outputs must not depend on what shares the batch."""
    params, cfg = tiny
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 12))).tolist()
               for _ in range(3)]

    solo_outputs = []
    for p in prompts:
        eng = ServingEngine(params, cfg, max_batch=1, max_len=64,
                            cache_dtype=jnp.float32)
        rid = eng.submit(p, max_new_tokens=5)
        solo_outputs.append(eng.run()[rid])

    eng = ServingEngine(params, cfg, max_batch=4, max_len=64,
                        cache_dtype=jnp.float32)
    rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    batched = eng.run()
    for rid, want in zip(rids, solo_outputs):
        assert batched[rid] == want


def test_more_requests_than_slots(tiny):
    params, cfg = tiny
    rng = np.random.default_rng(2)
    engine = ServingEngine(params, cfg, max_batch=2, max_len=64,
                           cache_dtype=jnp.float32)
    rids = [engine.submit(rng.integers(0, cfg.vocab_size, size=5).tolist(),
                          max_new_tokens=4) for _ in range(5)]
    outputs = engine.run()
    assert sorted(outputs) == sorted(rids)
    assert all(len(v) == 4 for v in outputs.values())


def test_ssm_engine_roundtrip():
    cfg = registry.get("mamba2-130m").model(reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    engine = ServingEngine(params, cfg, max_batch=2, max_len=64,
                           cache_dtype=jnp.float32)
    rid = engine.submit([1, 2, 3, 4], max_new_tokens=5)
    out = engine.run()[rid]
    want = _manual_greedy(params, cfg, [1, 2, 3, 4], 5)
    assert out == want

"""Per-arch smoke tests (assignment requirement): a REDUCED config of each
family runs one forward + one train step on CPU, asserting output shapes and
no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, TrainConfig, registry
from repro.models import lm
from repro.train import init_state, make_train_step

ARCHS = registry.list_archs()


def _batch(cfg, key, b=2, s=32):
    kt, ke = jax.random.split(key)
    batch = {"labels": jax.random.randint(kt, (b, s), 0, cfg.vocab_size)}
    if cfg.embedding_inputs:
        batch["embeds"] = jax.random.normal(ke, (b, s, cfg.d_model),
                                            jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ke, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = registry.get(arch).model(reduced=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    b, s = 2, 32
    batch = _batch(cfg, key, b, s)
    logits, aux, _ = lm.forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds")
    )
    assert logits.shape == (b, s, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all()
    for v in aux.values():
        assert np.isfinite(float(v))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    spec = registry.get(arch)
    cfg = spec.model(reduced=True)
    tcfg = TrainConfig(
        global_batch=2, seq_len=32,
        optimizer=OptimizerConfig(name=spec.optimizer, lr=1e-3,
                                  warmup_steps=1, total_steps=4),
    )
    key = jax.random.PRNGKey(1)
    state = init_state(key, cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, key)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # Parameters actually moved.
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], new_state["params"],
    )
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ["gemma3-27b", "zamba2-7b", "mamba2-130m",
                                  "deepseek-v2-lite-16b"])
def test_remat_matches_no_remat(arch):
    """Gradient checkpointing must not change the forward value."""
    cfg = registry.get(arch).model(reduced=True)
    key = jax.random.PRNGKey(2)
    params = lm.init_params(key, cfg)
    batch = _batch(cfg, key)
    args = dict(tokens=batch.get("tokens"), embeds=batch.get("embeds"))
    l1, _, _ = lm.forward(params, cfg, **args, remat="none")
    l2, _, _ = lm.forward(params, cfg, **args, remat="full")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-4, atol=2e-4)


def test_full_configs_match_assignment_dims():
    dims = {
        "pixtral-12b": (5120, 131072, 40),
        "deepseek-v2-lite-16b": (2048, 102400, 27),
        "llama4-maverick-400b-a17b": (5120, 202048, 48),
        "internlm2-1.8b": (2048, 92544, 24),
        "qwen2.5-14b": (5120, 152064, 48),
        "gemma3-27b": (5376, 262144, 62),
        "granite-34b": (6144, 49152, 88),
        "zamba2-7b": (3584, 32000, 81),
        "musicgen-large": (2048, 2048, 48),
        "mamba2-130m": (768, 50280, 24),
    }
    for arch, (d, v, layers) in dims.items():
        cfg = registry.get(arch).model()
        assert cfg.d_model == d, arch
        assert cfg.vocab_size == v, arch
        assert cfg.n_layers() == layers, arch


def test_active_vs_total_params_moe():
    cfg = registry.get("llama4-maverick-400b-a17b").model(reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    total = lm.param_count(params)
    active = lm.active_param_count(params, cfg)
    assert active < total
    # top-1 of 8 experts -> 7/8 of routed expert params inactive.
    moe_blk = params["stage_0"]["blocks"]["1"]["moe"]
    routed = sum(int(moe_blk[k].size) for k in ("w_gate", "w_up", "w_down"))
    assert total - active == int(routed * 7 / 8)

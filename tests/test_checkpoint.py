"""Checkpointing: roundtrip, atomicity, async, GC, resharding."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "stage_0": {"blocks": {"0": {"w": jnp.asarray(
                rng.normal(size=(4, 8)), jnp.float32)}}},
            "embed": {"table": jnp.asarray(rng.normal(size=(16, 4)),
                                           jnp.bfloat16)},
        },
        "opt": {"m": {"x": jnp.zeros((3,), jnp.float32)},
                "count": jnp.asarray(7, jnp.int32)},
        "step": jnp.asarray(13, jnp.int32),
    }


def _assert_tree_equal(a, b):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x).astype(np.float32),
                                      np.asarray(y).astype(np.float32))


def test_save_load_roundtrip(tmp_path):
    tree = _tree()
    save_pytree(str(tmp_path / "ck"), tree, data_state='{"batches_served": 5}')
    loaded, ds = load_pytree(str(tmp_path / "ck"))
    _assert_tree_equal(tree, loaded)
    assert ds == '{"batches_served": 5}'
    # dtype preserved, including bfloat16.
    assert loaded["params"]["embed"]["table"].dtype == np.dtype("bfloat16")


def test_manager_commit_and_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3):
        mgr.save(step, _tree(step))
    assert mgr.all_steps() == [2, 3]  # keep=2 garbage-collects step 1
    tree, _, step = mgr.restore()
    assert step == 3
    _assert_tree_equal(tree, jax.device_get(_tree(3)))


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    # Simulate a torn write: step dir exists but no COMMIT.
    torn = tmp_path / "step_00000002"
    shutil.copytree(tmp_path / "step_00000001", torn)
    os.remove(torn / "COMMIT")
    assert mgr.latest_step() == 1
    _, _, step = mgr.restore()
    assert step == 1


def test_async_save_and_hook(tmp_path):
    events = []
    mgr = CheckpointManager(str(tmp_path))
    mgr.on_commit = lambda step, nbytes: events.append((step, nbytes))
    mgr.save(5, _tree(5), async_=True)
    mgr.wait()
    assert mgr.latest_step() == 5
    assert events and events[0][0] == 5 and events[0][1] > 0


def test_restore_sharded_places_on_devices(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(9)
    mgr.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    placed, _, _ = mgr.restore_sharded(shardings)
    _assert_tree_equal(tree, placed)
    assert all(
        isinstance(x, jax.Array) for x in jax.tree.leaves(placed)
    )


def test_restore_empty_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore()

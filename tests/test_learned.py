"""Learned scheduling policy: features, data generator, training, policy.

DESIGN.md §15.  Covers the ISSUE-9 satellite contract for the training
data generator (seed-reproducibility, mask respect, ragged round-trip
without padding leakage) plus the policy surface: registration, feasible
plans with stamped meta, batched-vs-solo parity, LP fallback recording,
checkpoint round-trip, and a <=20-step CPU training smoke.
"""

import dataclasses
import importlib

import numpy as np
import pytest

from repro import learned
from repro.core import api, problem, ragged, trace
from repro.core.feasibility import check_plan
from repro.core.plan import InfeasibleError, Plan
from repro.learned import features as F
from repro.learned import model as M
from repro.learned import policy as P

# ``learned.train`` the *function* shadows the submodule on the package,
# so fetch the module itself.
T = importlib.import_module("repro.learned.train")

PATH = ("US-NM", "US-WY", "US-SD")

TINY_DATA = T.DataConfig(n_problems=4, jobs_range=(2, 5))
TINY_MODEL = M.LearnedModelConfig(d_model=8, n_heads=2, head_dim=4, hidden=16)


@pytest.fixture(scope="module")
def small_problem():
    traces = trace.make_trace_set(PATH, hours=72, seed=0)
    reqs = problem.paper_workload(n_jobs=5, seed=3)
    return problem.build_problem(reqs, traces, capacity_gbps=0.5)


@pytest.fixture(scope="module")
def tiny_dataset():
    return T.build_dataset(TINY_DATA, seed=11)


# ------------------------------------------------------------------ features

def test_featurize_shapes_and_mask_zeroing(small_problem):
    feats = F.featurize(small_problem)
    assert feats.shape == (small_problem.n_jobs, small_problem.n_slots,
                           F.N_FEATURES)
    assert feats.dtype == np.float32
    # every plane is zero outside the allowed-slot mask
    outside = ~small_problem.mask
    assert np.all(feats[outside] == 0.0)
    # the mask plane is the mask
    np.testing.assert_array_equal(feats[..., 2] > 0, small_problem.mask)


def test_featurize_commutes_with_padding(small_problem):
    """Bucket padding must not perturb real cells (no padding leakage)."""
    feats = F.featurize(small_problem)
    bj, bs = ragged.bucket_shape(small_problem.n_jobs + 3,
                                 small_problem.n_slots + 17)
    padded = F.featurize(ragged.pad_problem(small_problem, bj, bs))
    np.testing.assert_array_equal(
        padded[:small_problem.n_jobs, :small_problem.n_slots], feats)
    assert np.all(padded[small_problem.n_jobs:] == 0.0)
    assert np.all(padded[:, small_problem.n_slots:] == 0.0)


def test_featurize_fleet_raggged_buckets():
    triples = T.sample_fleet(TINY_DATA, seed=5)
    problems = [p for _, _, p in triples]
    batch, padded = F.featurize_fleet(problems)
    bj, bs = batch.bucket
    assert (bj, bs) == ragged.bucket_shape(max(p.n_jobs for p in problems),
                                           max(p.n_slots for p in problems))
    for b, p in enumerate(problems):
        np.testing.assert_array_equal(batch.features[b, :p.n_jobs, :p.n_slots],
                                      F.featurize(p))
        assert not batch.mask[b, p.n_jobs:].any()
        assert batch.size_bits[b, p.n_jobs:].sum() == 0.0


# -------------------------------------------------------------- data generator

def test_dataset_seed_reproducible():
    a = T.build_dataset(TINY_DATA, seed=11)
    b = T.build_dataset(TINY_DATA, seed=11)
    np.testing.assert_array_equal(a.batch.features, b.batch.features)
    np.testing.assert_array_equal(a.batch.mask, b.batch.mask)
    np.testing.assert_array_equal(a.targets, b.targets)
    assert a.batch.shapes == b.batch.shapes


def test_dataset_different_seed_differs():
    a = T.build_dataset(TINY_DATA, seed=11)
    c = T.build_dataset(TINY_DATA, seed=12)
    assert not (a.batch.shapes == c.batch.shapes
                and np.array_equal(a.batch.features, c.batch.features))


def test_dataset_targets_respect_masks(tiny_dataset):
    ds = tiny_dataset
    assert np.all(ds.targets[~ds.batch.mask] == 0.0)
    # LP fraction targets sum to ~1 over each real job's allowed slots
    sums = ds.targets.sum(axis=2)[ds.job_mask]
    np.testing.assert_allclose(sums, 1.0, atol=1e-5)
    # pad jobs are dead rows
    assert np.all(ds.targets.sum(axis=2)[~ds.job_mask] == 0.0)


def test_sample_fleet_is_feasible_and_deterministic():
    a = T.sample_fleet(TINY_DATA, seed=3)
    b = T.sample_fleet(TINY_DATA, seed=3)
    from repro.core.feasibility import workload_feasible

    for (_, _, pa), (_, _, pb) in zip(a, b):
        assert workload_feasible(pa)[0]
        np.testing.assert_array_equal(pa.cost, pb.cost)
        np.testing.assert_array_equal(pa.size_bits, pb.size_bits)


# ------------------------------------------------------------------- model

def test_forward_masked_softmax_properties(tiny_dataset):
    ds = tiny_dataset
    params = M.init_params(__import__("jax").random.PRNGKey(0), TINY_MODEL)
    frac = M.fractions(params, ds.batch, TINY_MODEL)
    assert frac.shape == ds.batch.mask.shape
    assert np.all(frac >= 0.0)
    assert np.all(frac[~ds.batch.mask] == 0.0)
    np.testing.assert_allclose(frac.sum(axis=2)[ds.job_mask], 1.0, atol=1e-5)
    # all-masked (pad) jobs emit exactly zero, never a uniform leak
    assert np.all(frac.sum(axis=2)[~ds.job_mask] == 0.0)


def test_concentrate_delivers_bytes_at_rate_cap(small_problem):
    p = small_problem
    rng = np.random.default_rng(0)
    frac = np.where(p.mask, rng.random((1, p.n_jobs, p.n_slots)), 0.0)
    rho = P.concentrate(frac, p.size_bits[None], np.array([p.slot_seconds]),
                        np.array([p.rate_cap_bps]), p.mask[None])[0]
    np.testing.assert_allclose(rho.sum(axis=1) * p.slot_seconds, p.size_bits,
                               rtol=1e-12)
    assert rho.max() <= p.rate_cap_bps * (1 + 1e-12)
    assert np.all(rho[~p.mask] == 0.0)
    # bytes land on the highest-fraction slots first
    used = rho > 0
    for i in range(p.n_jobs):
        if used[i].any():
            assert frac[0, i][used[i]].min() >= frac[0, i][~used[i]].max()


# ------------------------------------------------------------------- policy

def test_registered_and_plannable_through_scheduler(small_problem):
    assert "lints-learned" in api.available_policies()
    plan = api.Scheduler("lints-learned").plan(small_problem)
    assert plan.meta["policy"] == "lints-learned"
    assert plan.meta["learned"]["trained"] is False  # registry default
    assert check_plan(small_problem, plan.rho_bps, rel_tol=1e-5).feasible


def test_ragged_plan_batch_matches_solo_plans():
    """Fleet planning through one bucket == per-problem plans, no leakage."""
    problems = [p for _, _, p in T.sample_fleet(TINY_DATA, seed=7)]
    assert len({(p.n_jobs, p.n_slots) for p in problems}) > 1, "want ragged"
    pol = api.get_policy("lints-learned")
    batch_plans = pol.plan_batch(problems)
    for i, (p, bp) in enumerate(zip(problems, batch_plans)):
        solo = pol.plan(p)
        np.testing.assert_allclose(bp.rho_bps, solo.rho_bps, atol=1e-9)
        assert bp.rho_bps.shape == (p.n_jobs, p.n_slots)
        assert bp.meta["batch_index"] == i
        assert check_plan(p, bp.rho_bps, rel_tol=1e-5).feasible


def test_policy_infeasible_workload_raises(small_problem):
    impossible = dataclasses.replace(
        small_problem, size_bits=small_problem.size_bits * 1e6)
    with pytest.raises(InfeasibleError):
        api.get_policy("lints-learned").plan(impossible)


def test_validation_failure_falls_back_to_lp(small_problem, monkeypatch):
    """A hardening failure ships the LP plan and records it in meta."""

    def broken_harden(self, problem, soft):
        raise InfeasibleError("forced hardening failure")

    monkeypatch.setattr(P.LearnedPolicy, "_harden_batch",
                        lambda self, problems, padded, soft:
                        ([None] * len(problems),
                         ["forced hardening failure"] * len(problems)))
    plan = api.get_policy("lints-learned").plan(small_problem)
    assert plan.meta["policy"] == "lints-learned"
    assert plan.meta["fallback"] == "lints"
    assert plan.meta["fallback_reason"] == "forced hardening failure"
    assert check_plan(small_problem, plan.rho_bps, rel_tol=1e-5).feasible


def test_policy_overrides_via_registry():
    pol = api.get_policy("lints-learned", vertex_round=False,
                         fallback="edf")
    assert pol.vertex_round is False and pol.fallback == "edf"


# ---------------------------------------------------------------- training

def test_training_smoke_improves_loss(tiny_dataset):
    """<=20 steps on CPU: loss must drop and the result must plan."""
    params, history = T.train(tiny_dataset, TINY_MODEL, steps=15, seed=0)
    assert len(history) == 15
    assert history[-1]["loss"] < history[0]["loss"]
    pol = P.LearnedPolicy(params=params, model=TINY_MODEL)
    prob = [p for _, _, p in T.sample_fleet(TINY_DATA, seed=21)][0]
    plan = pol.plan(prob)
    assert plan.meta["learned"]["trained"] is True
    assert check_plan(prob, plan.rho_bps, rel_tol=1e-5).feasible


def test_train_checkpoint_roundtrip(tiny_dataset, tmp_path):
    params, _ = T.train(tiny_dataset, TINY_MODEL, steps=3, seed=0,
                        checkpoint_dir=str(tmp_path))
    restored = T.load_params(str(tmp_path))
    prob = [p for _, _, p in T.sample_fleet(TINY_DATA, seed=22)][0]
    a = P.LearnedPolicy(params=params, model=TINY_MODEL).plan(prob)
    b = P.LearnedPolicy(params=restored, model=TINY_MODEL).plan(prob)
    np.testing.assert_allclose(a.rho_bps, b.rho_bps)


def test_training_is_seed_deterministic(tiny_dataset):
    pa, _ = T.train(tiny_dataset, TINY_MODEL, steps=3, seed=4)
    pb, _ = T.train(tiny_dataset, TINY_MODEL, steps=3, seed=4)
    import jax

    for la, lb in zip(jax.tree_util.tree_leaves(pa),
                      jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ------------------------------------------------------------- integrations

def test_transfer_manager_plans_with_learned_policy():
    from repro.transfer import Datacenter, Topology, TransferManager

    zones = ("US-NM", "US-SC")
    traces = trace.make_trace_set(zones, hours=24, seed=2)
    topo = Topology(datacenters=(Datacenter("a", "US-NM"),
                                 Datacenter("b", "US-SC")),
                    routes={("a", "b"): zones})
    tm = TransferManager(topo, traces, capacity_gbps=1.0,
                         policy="lints-learned")
    tm.enqueue(4.0, "a", "b", deadline_slots=48, request_id="t0")
    tm.run_until_idle()
    report = tm.report()
    assert report["completed"] == 1
    assert report["sla_violations"] == 0


def test_evaluate_ensemble_judges_learned_policy(small_problem):
    from repro.core.montecarlo import evaluate_ensemble

    traces = trace.make_trace_set(PATH, hours=72, seed=0)
    reqs = problem.paper_workload(n_jobs=5, seed=3)
    plans = [api.get_policy(n).plan(small_problem)
             for n in ("lints", "edf", "lints-learned")]
    reports = evaluate_ensemble(small_problem, plans, sigma=0.05, n_draws=4,
                                requests=reqs, traces=traces, seed=0)
    assert "lints-learned" in reports
    assert reports["lints-learned"].sla_violations == 0
    assert reports["lints-learned"].mean_gco2 <= reports["edf"].mean_gco2

"""LP solver correctness: SciPy backend (paper-faithful) vs JAX PDHG (ours).

The PDHG solver is validated against the HiGHS oracle: same objective
(within tolerance), feasible plans, on both the paper's workload shape and
random problems.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep: skip module cleanly when absent
from hypothesis import given, settings, strategies as st

from conftest import random_problem
from repro.core import api, lints
from repro.core.feasibility import check_plan, workload_feasible
from repro.core.pdhg import PDHGConfig, normalize_problem, pdhg_solve, solve_pdhg, vertex_round
from repro.core.scipy_backend import solve_scipy

PD_CFG = PDHGConfig(max_iters=30_000, check_every=200, tol=2e-5)


def test_scipy_plan_feasible(small_problem):
    plan = solve_scipy(small_problem)
    report = check_plan(small_problem, plan.rho_bps)
    assert report.feasible, report
    assert plan.meta["n_variables"] == small_problem.dim_rho()


def test_pdhg_matches_scipy_objective(small_problem):
    ref = solve_scipy(small_problem)
    got = solve_pdhg(small_problem, PD_CFG)
    assert check_plan(small_problem, got.rho_bps).feasible
    assert got.meta["objective"] <= ref.meta["objective"] * 1.005 + 1e-9


def test_vertex_round_keeps_feasibility_and_objective(small_problem):
    raw = solve_pdhg(small_problem, PD_CFG)
    rounded = vertex_round(small_problem, raw)
    assert check_plan(small_problem, rounded.rho_bps).feasible
    ref = solve_scipy(small_problem)
    assert rounded.meta["objective_rounded"] <= ref.meta["objective"] * 1.02
    # Rounding concentrates: no more active cells than before.
    assert (rounded.rho_bps > 0).sum() <= (raw.rho_bps > 0).sum()


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_pdhg_feasible_and_near_optimal_random(seed):
    rng = np.random.default_rng(seed)
    prob = random_problem(rng)
    ok, _ = workload_feasible(prob)
    if not ok:
        return  # generator aims for feasible; skip rare infeasible draws
    ref = solve_scipy(prob)
    got = solve_pdhg(prob, PD_CFG)
    assert check_plan(prob, got.rho_bps).feasible
    rel = (got.meta["objective"] - ref.meta["objective"]) / max(
        abs(ref.meta["objective"]), 1e-9
    )
    assert rel <= 0.01


def test_pdhg_kernel_path_matches_jnp_path(small_problem):
    cfg_k = PDHGConfig(max_iters=4000, check_every=200, use_kernel=True)
    cfg_j = PDHGConfig(max_iters=4000, check_every=200, use_kernel=False)
    a = solve_pdhg(small_problem, cfg_k)
    b = solve_pdhg(small_problem, cfg_j)
    assert a.meta["objective"] == pytest.approx(b.meta["objective"], rel=1e-3)


def test_lints_api_backends_agree(small_problem):
    sp = api.get_policy("lints").plan(small_problem)
    pd = api.get_policy("lints_pdhg", config=lints.LinTSConfig(
        backend="pdhg", pdhg=PD_CFG)).plan(small_problem)
    assert pd.objective(small_problem) <= sp.objective(small_problem) * 1.02


def test_infeasible_workload_raises(paper_traces):
    from repro.core.problem import TransferRequest

    reqs = [TransferRequest(size_gb=1e6, deadline_slots=4,
                            path=("US-NM",), request_id="huge")]
    prob = lints.build(reqs, paper_traces, capacity_gbps=0.25)
    with pytest.raises(lints.InfeasibleError):
        api.get_policy("lints").plan(prob)


def test_batched_pdhg_solves_multiple_problems(paper_traces):
    from repro.core import problem as prob_mod
    from repro.core.pdhg import pdhg_solve_batch
    import jax.numpy as jnp

    probs = [
        lints.build(prob_mod.paper_workload(n_jobs=6, seed=s), paper_traces, 0.5)
        for s in range(3)
    ]
    tensors = [normalize_problem(p) for p in probs]
    c = jnp.stack([t[0] for t in tensors])
    ub = jnp.stack([t[1] for t in tensors])
    br = jnp.stack([t[2] for t in tensors])
    bc = jnp.stack([t[3] for t in tensors])
    xs, _ = pdhg_solve_batch(c, ub, br, bc, max_iters=20_000)
    for i, p in enumerate(probs):
        rho = np.asarray(xs[i], np.float64) * p.rate_cap_bps
        from repro.core.feasibility import repair_plan
        rho = repair_plan(p, rho)
        ref = solve_scipy(p)
        got_obj = float((p.cost * rho).sum())
        assert got_obj <= ref.meta["objective"] * 1.02

"""Attention correctness: blocked==einsum, GQA reference, windows, caches,
prefill+decode == full forward, MLA absorbed decode == naive attention."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import AttentionConfig, ModelConfig, gqa, dense_stage, BlockConfig
from repro.models import attention as attn_mod
from repro.models import lm


def _rand_qkv(key, b, s, h, hkv, dh):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, s, h, dh), jnp.float32)
    k = jax.random.normal(kk, (b, s, hkv, dh), jnp.float32)
    v = jax.random.normal(kv, (b, s, hkv, dh), jnp.float32)
    return q, k, v


def _naive_reference(q, k, v, window=None):
    """Per-head loop reference with repeated KV."""
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    k = np.repeat(np.asarray(k, np.float64), rep, axis=2)
    v = np.repeat(np.asarray(v, np.float64), rep, axis=2)
    q = np.asarray(q, np.float64)
    out = np.zeros_like(q)
    for bi in range(b):
        for hi in range(h):
            scores = q[bi, :, hi] @ k[bi, :, hi].T / np.sqrt(dh)
            for i in range(s):
                for j in range(s):
                    if j > i or (window is not None and i - j >= window):
                        scores[i, j] = -np.inf
            w = np.exp(scores - scores.max(axis=-1, keepdims=True))
            w /= w.sum(axis=-1, keepdims=True)
            out[bi, :, hi] = w @ v[bi, :, hi]
    return out


@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("hkv", [4, 2, 1])
def test_einsum_attention_matches_naive(window, hkv):
    b, s, h, dh = 2, 24, 4, 16
    q, k, v = _rand_qkv(jax.random.PRNGKey(0), b, s, h, hkv, dh)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    got = attn_mod.attention_einsum(q, k, v, pos, pos, window=window,
                                    compute_dtype=jnp.float32)
    want = _naive_reference(q, k, v, window)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("s", [32, 100, 256])
def test_blocked_matches_einsum(window, s):
    b, h, hkv, dh = 2, 4, 2, 16
    q, k, v = _rand_qkv(jax.random.PRNGKey(1), b, s, h, hkv, dh)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    a = attn_mod.attention_einsum(q, k, v, pos, pos, window=window,
                                  compute_dtype=jnp.float32)
    bl = attn_mod.attention_blocked(q, k, v, pos, pos, window=window,
                                    compute_dtype=jnp.float32,
                                    block_q=32, block_kv=48)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bl),
                               rtol=3e-4, atol=3e-4)


def _dropless(cfg: ModelConfig) -> ModelConfig:
    """Capacity factor = num_experts: GShard capacity dropping is group-size
    dependent, so exact train/decode equivalence needs the dropless regime."""
    stages = []
    for st_ in cfg.stages:
        blocks = []
        for blk in st_.blocks:
            if blk.moe is not None:
                blk = dataclasses.replace(
                    blk, moe=dataclasses.replace(
                        blk.moe, capacity_factor=float(blk.moe.num_experts)))
            blocks.append(blk)
        stages.append(dataclasses.replace(st_, blocks=tuple(blocks)))
    return dataclasses.replace(cfg, stages=tuple(stages))


def _decode_matches_forward(arch: str, s=24, b=2):
    cfg = registry.get(arch).model(reduced=True)
    cfg = _dropless(dataclasses.replace(cfg, compute_dtype="float32"))
    key = jax.random.PRNGKey(3)
    params = lm.init_params(key, cfg)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)

    full_logits, _, _ = lm.forward(params, cfg, tokens=tokens)

    cache = lm.init_cache(cfg, b, s + 4, jnp.float32)
    n_prefill = s // 2
    _, cache = lm.prefill(params, cfg, tokens=tokens[:, :n_prefill],
                          cache=cache)
    lengths = jnp.full((b,), n_prefill, jnp.int32)
    logits_steps = []
    for t in range(n_prefill, s):
        logits, cache = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                       cache, lengths)
        logits_steps.append(logits[:, 0])
        lengths = lengths + 1
    got = jnp.stack(logits_steps, axis=1)          # (b, s-n_prefill, V)
    want = full_logits[:, n_prefill:]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", [
    "internlm2-1.8b",        # GQA
    "qwen2.5-14b",           # GQA + bias
    "granite-34b",           # MQA
    "gemma3-27b",            # local:global pattern + qk-norm + post-norms
    "deepseek-v2-lite-16b",  # MLA absorbed decode + MoE
    "zamba2-7b",             # hybrid mamba + shared attn
    "mamba2-130m",           # pure SSM recurrent decode
    "llama4-maverick-400b-a17b",  # alternating dense/MoE
])
def test_prefill_plus_decode_matches_full_forward(arch):
    """The strongest equivalence we have: KV/state caches + decode paths
    (incl. MLA absorption, ring buffers, SSD recurrence) must reproduce the
    full parallel forward, token for token."""
    _decode_matches_forward(arch)


def test_ring_cache_sliding_window_decode():
    """Decode far past the window: ring cache must equal full-context attn
    with the same window."""
    acfg = gqa(2, 2, 8, window=8)
    block = BlockConfig(kind="attn_mlp", attention=acfg, mlp_dim=32)
    cfg = ModelConfig(
        name="tiny-swa", family="dense", d_model=16, vocab_size=64,
        stages=(dense_stage(block, 2),), max_seq_len=128,
        compute_dtype="float32",
    )
    key = jax.random.PRNGKey(5)
    params = lm.init_params(key, cfg)
    b, s = 1, 40
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(params, cfg, tokens=tokens)

    cache = lm.init_cache(cfg, b, s, jnp.float32)  # ring capacity = window 8
    _, cache = lm.prefill(params, cfg, tokens=tokens[:, :4], cache=cache)
    lengths = jnp.full((b,), 4, jnp.int32)
    outs = []
    for t in range(4, s):
        logits, cache = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                       cache, lengths)
        outs.append(logits[:, 0])
        lengths = lengths + 1
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(full_logits[:, 4:]),
                               rtol=2e-3, atol=2e-3)


def test_prefill_longer_than_ring_cache():
    """Prefill of S > window must keep exactly the last `window` keys."""
    acfg = gqa(2, 2, 8, window=8)
    block = BlockConfig(kind="attn_mlp", attention=acfg, mlp_dim=32)
    cfg = ModelConfig(
        name="tiny-swa2", family="dense", d_model=16, vocab_size=64,
        stages=(dense_stage(block, 1),), max_seq_len=128,
        compute_dtype="float32",
    )
    key = jax.random.PRNGKey(6)
    params = lm.init_params(key, cfg)
    b, s = 1, 20
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(params, cfg, tokens=tokens)
    cache = lm.init_cache(cfg, b, s, jnp.float32)
    _, cache = lm.prefill(params, cfg, tokens=tokens[:, :16], cache=cache)
    lengths = jnp.full((b,), 16, jnp.int32)
    outs = []
    for t in range(16, s):
        logits, cache = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                       cache, lengths)
        outs.append(logits[:, 0])
        lengths = lengths + 1
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(full_logits[:, 16:]),
                               rtol=2e-3, atol=2e-3)

"""Mamba2/SSD: chunked parallel form vs step-by-step recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.models import mamba2


def _naive_recurrent(xh, dt, a_log, bmat, cmat):
    """Pure-numpy per-step SSM recurrence (the semantics of record)."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    a = -np.exp(np.asarray(a_log, np.float64))
    x = np.asarray(xh, np.float64)
    dt = np.asarray(dt, np.float64)
    B = np.asarray(bmat, np.float64)
    C = np.asarray(cmat, np.float64)
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        decay = np.exp(dt[:, t] * a[None, :])                    # (b,h)
        dbx = np.einsum("bn,bhp->bhpn", B[:, t], x[:, t] * dt[:, t][..., None])
        state = state * decay[:, :, None, None] + dbx
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, C[:, t])
    return ys, state


@pytest.mark.parametrize("s,chunk", [(16, 4), (32, 8), (33, 8), (8, 16)])
def test_ssd_chunked_matches_recurrence(s, chunk):
    key = jax.random.PRNGKey(0)
    b, h, p, n = 2, 3, 4, 8
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.uniform(ks[2], (h,), minval=0.0, maxval=1.5)
    bmat = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    cmat = jax.random.normal(ks[4], (b, s, n), jnp.float32)

    y, final = mamba2._ssd_chunked(xh, dt, a_log, bmat, cmat, chunk)
    y_ref, final_ref = _naive_recurrent(xh, dt, a_log, bmat, cmat)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(final), final_ref, rtol=2e-3,
                               atol=2e-3)


def test_mamba_block_prefill_then_decode_matches_parallel():
    scfg = SSMConfig(d_state=8, head_dim=4, expand=2, conv_width=4, chunk=8)
    d_model = 16
    key = jax.random.PRNGKey(1)
    params = mamba2.mamba_init(key, scfg, d_model, jnp.float32)
    b, s = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(2), (b, s, d_model), jnp.float32)

    y_full, _ = mamba2.mamba_apply(params, scfg, d_model, x, mode="train",
                                   compute_dtype=jnp.float32)

    n_pre = 10
    cache = mamba2.make_ssm_cache(scfg, d_model, b, jnp.float32)
    y_pre, cache = mamba2.mamba_apply(params, scfg, d_model, x[:, :n_pre],
                                      cache=cache, mode="prefill",
                                      compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :n_pre]),
                               rtol=2e-3, atol=2e-3)
    outs = []
    for t in range(n_pre, s):
        y_t, cache = mamba2.mamba_apply(params, scfg, d_model, x[:, t:t + 1],
                                        cache=cache, mode="decode",
                                        compute_dtype=jnp.float32)
        outs.append(y_t[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full[:, n_pre:]),
                               rtol=5e-3, atol=5e-3)


def test_ssd_state_decay_bounds():
    """Decays must stay in (0, 1]: positive dt, negative A."""
    scfg = SSMConfig(d_state=8, head_dim=4)
    params = mamba2.mamba_init(jax.random.PRNGKey(3), scfg, 16, jnp.float32)
    a = -np.exp(np.asarray(params["a_log"]))
    assert (a < 0).all()
    lo, hi = scfg.a_init_range
    assert (np.exp(np.asarray(params["a_log"])) >= lo - 1e-6).all()
    assert (np.exp(np.asarray(params["a_log"])) <= hi + 1e-6).all()

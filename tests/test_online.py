"""Online scheduling service: events, warm-started replans, service facade.

DESIGN.md §13.  The load-bearing guarantee is warm-start *parity*: an
incremental replan (resumed from the previous solve's primal/dual
iterates) must land on the same objective as a cold solve to ≤ 1e-6
relative — across arrival/completion/forecast-revision deltas, across
ragged bucket boundaries, and after a solver-fault ladder rung.  The
benchmarks (``benchmarks/online.py``) assert the same gate at scale.
"""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, lints
from repro.core.faults import FaultSchedule, SolverFault
from repro.core.pdhg import PDHGConfig
from repro.core.problem import TransferRequest, build_problem
from repro.core.trace import make_trace_set
from repro.transfer import (
    AdmissionError,
    Datacenter,
    Topology,
    TransferManager,
    TransferService,
)
from repro.transfer import events as ev
from repro.transfer.planner import IncrementalPlanner, ReplanTelemetry

ZONES = ("US-NM", "US-WY", "US-SC")

# f64 + tight tol so the 1e-6 parity bound measures the solver, not float
# noise; no rounding so objectives compare exactly.
CFG = lints.LinTSConfig(
    backend="pdhg", vertex_round=False, refine=False,
    pdhg=PDHGConfig(dtype=jnp.float64, tol=1e-7, max_iters=60_000,
                    check_every=100),
)


@pytest.fixture(autouse=True)
def _x64():
    from jax.experimental import enable_x64

    with enable_x64():
        yield


def _traces(hours=24, seed=0):
    return make_trace_set(ZONES, hours=hours, seed=seed)


def _problem(n_jobs, traces, *, offset=0, seed=0, skip=()):
    rng = np.random.default_rng(seed)
    sizes = rng.uniform(2.0, 8.0, size=n_jobs)
    reqs = [
        TransferRequest(size_gb=float(sizes[i]),
                        deadline_slots=traces.n_slots,
                        offset_slots=offset,
                        path=ZONES, request_id=f"job-{i}")
        for i in range(n_jobs) if i not in skip
    ]
    return build_problem(reqs, traces, 2.0)


def _rids(problem_n, skip=()):
    return [f"job-{i}" for i in range(problem_n) if i not in skip]


def _parity(plan_a, plan_b, tol=1e-6):
    a, b = plan_a.meta["objective"], plan_b.meta["objective"]
    assert abs(a - b) / max(abs(b), 1e-30) <= tol


# ---------------------------------------------------------------------------
# Warm-start correctness
# ---------------------------------------------------------------------------

def test_warm_after_arrival_matches_cold():
    traces = _traces()
    planner = IncrementalPlanner(api.get_policy("lints_pdhg", config=CFG))
    p0 = _problem(4, traces)
    planner.plan(p0, _rids(4), resilient=False)
    p1 = _problem(5, traces)      # one arrival, same 4->8 job bucket
    warm = planner.plan(p1, _rids(5), resilient=False)
    assert warm.meta["warm_started"]
    cold = lints._solve_incremental(p1, CFG)
    _parity(warm, cold)


def test_warm_after_completion_matches_cold():
    traces = _traces()
    planner = IncrementalPlanner(api.get_policy("lints_pdhg", config=CFG))
    planner.plan(_problem(5, traces), _rids(5), resilient=False)
    p1 = _problem(5, traces, skip=(2,))   # one departure drops its row
    warm = planner.plan(p1, _rids(5, skip=(2,)), resilient=False)
    assert warm.meta["warm_started"]
    _parity(warm, lints._solve_incremental(p1, CFG))


def test_warm_after_forecast_revision_matches_cold():
    traces = _traces()
    planner = IncrementalPlanner(api.get_policy("lints_pdhg", config=CFG))
    planner.plan(_problem(4, traces), _rids(4), resilient=False)
    p1 = _problem(4, _traces(seed=3))     # revised costs, same rows
    warm = planner.plan(p1, _rids(4), resilient=False)
    assert warm.meta["warm_started"]
    _parity(warm, lints._solve_incremental(p1, CFG))


def test_warm_across_bucket_boundary_matches_cold():
    """4 jobs buckets to 4 rows; the 5th crosses to the 8-row bucket —
    the warm rows must survive the re-pad."""
    traces = _traces()
    from repro.core import ragged

    assert ragged.bucket_shape(4, traces.n_slots)[0] == 4
    assert ragged.bucket_shape(5, traces.n_slots)[0] == 8
    planner = IncrementalPlanner(api.get_policy("lints_pdhg", config=CFG))
    planner.plan(_problem(4, traces), _rids(4), resilient=False)
    p1 = _problem(5, traces)
    warm = planner.plan(p1, _rids(5), resilient=False)
    assert warm.meta["warm_started"]
    assert tuple(warm.meta["bucket_shape"])[0] == 8
    _parity(warm, lints._solve_incremental(p1, CFG))


def test_warm_after_solver_fault_rung_matches_cold():
    """rungs=1 poisons only the warm resume: the ladder falls back to the
    cold pdhg rung, and the NEXT warm replan (seeded from the fallback
    plan) still matches the cold solve."""
    traces = _traces()
    planner = IncrementalPlanner(api.get_policy("lints_pdhg", config=CFG))
    planner.plan(_problem(4, traces), _rids(4), resilient=False)
    p1 = _problem(5, traces)
    fault = SolverFault(solve_index=0, mode="nan", rungs=1)
    plan = planner.plan(p1, _rids(5), inject=fault, resilient=True)
    assert plan.meta["solver_status"] == "pdhg"   # warm rung was poisoned
    p2 = _problem(6, traces)
    warm = planner.plan(p2, _rids(6), resilient=False)
    assert warm.meta["warm_started"]              # reseeded from fallback
    _parity(warm, lints._solve_incremental(p2, CFG))


def test_resilient_warm_rung_reports_status():
    traces = _traces()
    p0 = _problem(4, traces)
    prev = lints._solve_incremental(p0, CFG)
    ws = prev.meta["warm_state"]
    plan = api.resilient_solve(
        p0, CFG, warm=api.WarmStart(x0_bps=ws["x_bps"], u0=ws["u"],
                                    v0=ws["v"]))
    assert plan.meta["solver_status"] == "pdhg-warm"
    assert plan.meta["warm_started"]
    _parity(plan, prev)


# ---------------------------------------------------------------------------
# Event queue + coalescing
# ---------------------------------------------------------------------------

def test_event_queue_dirty_tracking():
    q = ev.EventQueue()
    assert not q.replan_pending()
    q.post(ev.CompletionEvent(0, rid="a"))
    assert not q.replan_pending()      # informational events don't dirty
    q.post(ev.ArrivalEvent(0, rids=("b",)))
    assert q.replan_pending()
    q.discard_dirty()
    assert not q.replan_pending()
    assert len(q) == 1                 # completion survived the discard
    events = q.drain()
    assert len(events) == 1 and isinstance(events[0], ev.CompletionEvent)
    assert len(q) == 0


def test_coalesce_folds_burst_into_one_delta():
    events = [
        ev.ArrivalEvent(0, rids=("a", "b")),
        ev.ArrivalEvent(0, rids=("c",)),
        ev.CompletionEvent(1, rid="z"),
        ev.ForecastRevisionEvent(1, zones=("US-NM",)),
        ev.DriftEvent(2),
    ]
    delta = ev.coalesce(events)
    assert delta.arrived == ("a", "b", "c")
    assert delta.completed == ("z",)
    assert delta.forecast_revised and delta.drift
    assert delta.n_events == 5 and delta.n_dirty == 3


def _manager(policy="lints", **kw):
    traces = _traces(hours=72)
    topo = Topology(
        datacenters=(Datacenter("a", ZONES[0]), Datacenter("b", ZONES[-1])),
        routes={("a", "b"): ZONES, ("b", "a"): ZONES[::-1]},
    )
    config = (lints.LinTSConfig(backend="scipy")
              if policy == "lints" else None)
    return TransferManager(topo, traces, capacity_gbps=1.0,
                           policy=policy, config=config, **kw)


def test_enqueue_many_one_event_one_replan():
    tm = _manager()
    rids = tm.enqueue_many([
        (5.0, "a", "b", 96),
        {"size_gb": 2.0, "src": "a", "dst": "b", "deadline_slots": 48,
         "request_id": "named"},
    ])
    assert rids[1] == "named"
    assert len(tm.events) == 1          # ONE ArrivalEvent for the batch
    assert tm._needs_plan
    tm.replan()
    rep = tm.report()["replans"]
    assert rep["count"] == 1
    assert rep["events_coalesced_mean"] == 1.0
    assert all(rid in tm._plan_rho for rid in rids)


def test_needs_plan_setter_back_compat():
    tm = _manager()
    tm.enqueue(5.0, "a", "b", 96)
    assert tm._needs_plan
    tm._needs_plan = False              # old flag semantics must hold
    assert not tm._needs_plan
    tm._needs_plan = True
    assert tm._needs_plan
    tm.replan()
    assert not tm._needs_plan


def test_revise_forecast_marks_dirty_and_requires_same_grid():
    tm = _manager()
    tm.enqueue(5.0, "a", "b", 96)
    tm.replan()
    assert not tm._needs_plan
    tm.revise_forecast(_traces(hours=72, seed=9), zones=ZONES)
    assert tm._needs_plan
    with pytest.raises(ValueError):
        tm.revise_forecast(_traces(hours=24, seed=9))


def test_manager_warm_replans_with_pdhg_policy():
    tm = _manager(policy="lints_pdhg")
    tm.enqueue_many([(3.0, "a", "b", 200), (4.0, "a", "b", 220)])
    tm.replan()
    tm.enqueue(2.0, "a", "b", 180)
    tm.replan()
    rep = tm.report()["replans"]
    assert rep["count"] == 2
    assert rep["cold"] >= 1 and rep["warm"] >= 1
    assert np.isfinite(rep["latency_ms_p50"])
    assert np.isfinite(rep["latency_ms_p99"])


def test_telemetry_summary_shape_stable():
    t = ReplanTelemetry()
    s = t.summary()
    assert s["count"] == 0 and np.isnan(s["latency_ms_p50"])
    t.record(3.0, warm=True, events=4)
    s = t.summary()
    assert s == {"count": 1, "warm": 1, "cold": 0, "latency_ms_p50": 3.0,
                 "latency_ms_p99": 3.0, "events_coalesced_mean": 4.0}


# ---------------------------------------------------------------------------
# Service facade
# ---------------------------------------------------------------------------

def test_service_snapshot_immutable_and_versioned():
    svc = TransferService(_manager())
    rid = svc.submit(5.0, "a", "b", 96)
    v0 = svc.snapshot().version
    snap = svc.pump()
    assert snap.version > v0
    assert svc.rate(rid, 0) == snap.rate(rid, 0)
    assert snap.rate("unknown-rid") == 0.0
    assert snap.rate(rid, 10_000) == 0.0
    with pytest.raises(ValueError):
        snap.rates_bps[rid][0] = 1.0          # arrays are non-writeable
    with pytest.raises(TypeError):
        snap.rates_bps["x"] = np.zeros(3)     # mapping proxy is read-only


def test_service_admission_control():
    svc = TransferService(_manager(), max_pending=2)
    svc.submit(1.0, "a", "b", 96)
    svc.submit(1.0, "a", "b", 96)
    with pytest.raises(AdmissionError):
        svc.submit(1.0, "a", "b", 96)
    with pytest.raises(AdmissionError):
        svc.submit_many([(1.0, "a", "b", 96), (1.0, "a", "b", 96)])
    stats = svc.stats()
    assert stats["admitted"] == 2 and stats["rejected"] == 3


def test_submit_many_burst_straddling_max_pending_is_all_or_nothing():
    """A burst that would cross max_pending leaves ZERO partial admissions:
    neither the service stats nor the manager may record any of the burst."""
    tm = _manager()
    svc = TransferService(tm, max_pending=3)
    svc.submit(1.0, "a", "b", 96)
    before = dict(tm.transfers)
    burst = [(1.0, "a", "b", 96)] * 3           # 1 admitted + 3 > max_pending
    with pytest.raises(AdmissionError):
        svc.submit_many(burst)
    assert dict(tm.transfers) == before          # no partial enqueue
    assert svc.stats()["admitted"] == 1
    assert svc.stats()["rejected"] == len(burst)
    # the freed capacity is still usable: a fitting burst goes through whole
    rids = svc.submit_many([(1.0, "a", "b", 96), (1.0, "a", "b", 96)])
    assert len(rids) == 2 and all(r in tm.transfers for r in rids)


def test_enqueue_many_invalid_mid_burst_admits_nothing():
    """Manager-side transactionality: a bad request anywhere in the batch
    (validation happens during staging) must leave the manager untouched —
    no transfers registered, no ArrivalEvent posted."""
    tm = _manager()
    with pytest.raises(ValueError):
        tm.enqueue_many([
            (1.0, "a", "b", 96),
            (1.0, "a", "b", 0),                  # invalid deadline mid-burst
            (1.0, "a", "b", 48),
        ])
    assert not tm.transfers
    assert len(tm.events) == 0
    assert not tm._needs_plan


def test_service_worker_debounces_burst():
    tm = _manager()
    svc = TransferService(tm, debounce_s=0.05)
    svc.start()
    try:
        for i in range(6):
            svc.submit(1.0 + i, "a", "b", 96)
        snap = svc.quiesce()
        assert snap.version > 0
        rep = tm.report()["replans"]
        # Debouncing coalesces the burst into far fewer solves than
        # submissions (typically 1-2).
        assert 1 <= rep["count"] <= 3
        assert rep["events_coalesced_mean"] >= 2.0
    finally:
        svc.stop()


def test_service_tick_publishes_and_completes():
    svc = TransferService(_manager())
    rid = svc.submit(5.0, "a", "b", 96)
    for _ in range(96):
        if not svc.manager.pending():
            break
        svc.tick()
    t = svc.manager.transfers[rid]
    assert t.done_slot is not None and not t.violated
    assert rid not in svc.snapshot().pending


def test_service_concurrent_submit_and_read():
    svc = TransferService(_manager(), debounce_s=0.01)
    svc.start()
    errs = []

    def reader():
        try:
            for _ in range(200):
                snap = svc.snapshot()
                for rid in snap.pending:
                    snap.rate(rid)
        except Exception as e:          # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for th in threads:
        th.start()
    try:
        for i in range(8):
            svc.submit(1.0, "a", "b", 96, request_id=f"r{i}")
        svc.quiesce()
    finally:
        for th in threads:
            th.join()
        svc.stop()
    assert not errs
    assert svc.snapshot().version >= 1


# ---------------------------------------------------------------------------
# Event-driven chaos path (replayed by the chaos CI job)
# ---------------------------------------------------------------------------

def test_fault_events_flow_through_queue():
    import os

    seed = int(os.environ.get("REPRO_CHAOS_SEED", "1337"))
    zones = ("US-NM", "US-WY", "US-SD", "US-CO")
    primary = ("US-NM", "US-WY", "US-SD")
    alternate = ("US-NM", "US-CO", "US-SD")
    traces = make_trace_set(zones, hours=72, seed=0)
    topo = Topology(
        datacenters=(Datacenter("a", "US-NM"), Datacenter("b", "US-SD")),
        routes={("a", "b"): primary},
        alternates={("a", "b"): (alternate,)},
    )
    links = [tuple(sorted(p[i:i + 2]))
             for p in (primary, alternate) for i in range(len(p) - 1)]
    fs = FaultSchedule.chaos(seed, n_slots=48, links=links, zones=zones)
    tm = TransferManager(topo, traces, capacity_gbps=1.0, policy="lints",
                         config=lints.LinTSConfig(backend="scipy"),
                         faults=fs)
    tm.enqueue_many([(30.0, "a", "b", 60), (10.0, "a", "b", 40)])
    for _ in range(60):
        if not tm.pending():
            break
        tm.tick()
    rep = tm.report()
    # The engine survived the chaos schedule and kept its accounting.
    assert rep["completed"] + rep["pending"] + rep["sla_violations"] >= 2
    assert rep["replans"]["count"] >= 1
    # Informational events ride the same queue as dirty ones — the queue
    # never accumulates without bound (each replan drains everything).
    assert tm.events.posted >= tm.events.drained

"""Scenario-robust scheduling (DESIGN.md §14): CVaR objective math, the
HiGHS-oracle parity gate for the scenario-batched PDHG solve, warm resume,
the policy's degradation ladder + backend dispatch, the online
``wrap_problem`` hook (lead-ramped dispersion), and the rolling-horizon
replay loop.  The chaos-tier replay reproducibility test honours
``REPRO_CHAOS_SEED`` (same idiom as ``test_faults.py``)."""

import dataclasses
import os

import numpy as np
import pytest

from repro.core import api
from repro.core.faults import FaultSchedule
from repro.core.feasibility import check_plan
from repro.core.plan import InfeasibleError, Plan
from repro.core.problem import TransferRequest, build_problem
from repro.core.robust import (
    RobustConfig,
    RobustPolicy,
    RobustProblem,
    as_robust,
    build_robust_problem,
    robust_objective,
    robustify,
    solve_robust,
)
from repro.core.scipy_backend import solve_robust_scipy
from repro.core.simulator import (
    forecast_with_lead_noise,
    rolling_horizon_replay,
)
from repro.core.trace import TraceSet, make_trace_set

ZONES = ("US-NM", "US-WY", "US-SD")
N_SLOTS = 24

# Oracle-grade settings (RobustConfig.tol note): objective parity vs HiGHS
# at ≤1e-6 relative needs a tighter certificate than the shipped default.
PARITY_CFG = RobustConfig(backend="pdhg", tol=3e-7, max_iters=1_000_000)


def _traces(m=N_SLOTS, seed=0):
    rng = np.random.default_rng(seed)
    return TraceSet(
        slot_seconds=900.0,
        zone_slots={
            z: np.clip(rng.normal(400, 150, size=m), 20.0, None)
            for z in ZONES
        },
    )


def _requests(n=3, m=N_SLOTS, seed=1):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        deadline = int(rng.integers(m // 2, m + 1))
        offset = int(rng.integers(0, max(1, deadline - 6)))
        reqs.append(TransferRequest(
            size_gb=float(rng.uniform(50, 250)), deadline_slots=deadline,
            offset_slots=offset, path=ZONES, request_id=f"r{i}"))
    return reqs


@pytest.fixture(scope="module")
def robust_problem():
    return build_robust_problem(_requests(), _traces(), capacity_gbps=2.0,
                                sigma=0.15, n_draws=8, seed=11)


@pytest.fixture(scope="module")
def pdhg_plan(robust_problem):
    """One shared oracle-grade PDHG solve (jit compile paid once)."""
    return solve_robust(robust_problem, PARITY_CFG)


# ------------------------------------------------------------- objective

def test_robust_objective_blends_mean_and_cvar():
    rng = np.random.default_rng(4)
    draws = rng.uniform(0.5, 2.0, size=(6, 2, 5))
    rho = rng.uniform(0.0, 1.0, size=(2, 5))
    y = np.einsum("knm,nm->k", draws, rho)
    mean_only = robust_objective(draws, rho, cvar_alpha=0.5, cvar_weight=0.0)
    assert mean_only == pytest.approx(y.mean(), rel=1e-12)
    # alpha covering every scenario makes CVaR collapse to the mean
    degenerate = robust_objective(draws, rho, cvar_alpha=1.0, cvar_weight=1.0)
    assert degenerate == pytest.approx(y.mean(), rel=1e-12)
    # the CVaR leg can only raise the blend, and is monotone in weight
    lo = robust_objective(draws, rho, cvar_alpha=0.25, cvar_weight=0.3)
    hi = robust_objective(draws, rho, cvar_alpha=0.25, cvar_weight=0.9)
    assert mean_only <= lo <= hi
    # pure CVaR at alpha=1/K is the worst case
    worst = robust_objective(draws, rho, cvar_alpha=1.0 / 6, cvar_weight=1.0)
    assert worst == pytest.approx(y.max(), rel=1e-9)


def test_as_robust_validates_and_masks():
    base = build_problem(_requests(), _traces(), 2.0)
    draws = np.ones((4,) + base.cost.shape)
    rp = as_robust(base, draws)
    assert rp.n_draws == 4
    assert np.all(rp.cost_draws[:, ~base.mask] == 0.0)   # draws masked
    with pytest.raises(ValueError, match="leading draw axis"):
        as_robust(base, draws[:, :, :-1])
    with pytest.raises(ValueError, match="cvar_alpha"):
        as_robust(base, draws, cvar_alpha=0.0)
    with pytest.raises(ValueError, match="cvar_weight"):
        as_robust(base, draws, cvar_weight=1.5)


def test_robustify_synthesizes_and_is_idempotent():
    base = build_problem(_requests(), _traces(), 2.0)
    rp = robustify(base, n_draws=5, seed=3)
    assert isinstance(rp, RobustProblem) and rp.n_draws == 5
    assert robustify(rp) is rp
    # deterministic in the seed
    rp2 = robustify(base, n_draws=5, seed=3)
    np.testing.assert_array_equal(rp.cost_draws, rp2.cost_draws)


def test_solve_robust_requires_draws_and_feasibility():
    base = build_problem(_requests(), _traces(), 2.0)
    with pytest.raises(ValueError, match="cost_draws"):
        solve_robust(as_robust(base, np.zeros((0,) + base.cost.shape)))
    tiny = dataclasses.replace(
        robustify(base, n_draws=3),
        size_bits=base.size_bits * 1e6)          # undeliverable workload
    with pytest.raises(InfeasibleError, match="infeasible"):
        solve_robust(tiny)


# ---------------------------------------------------------------- parity

def test_pdhg_matches_scipy_oracle(robust_problem, pdhg_plan):
    """Acceptance: ≤1e-6 relative robust objective vs the HiGHS epigraph
    oracle (objective-space parity; argmins need not be unique)."""
    oracle = solve_robust_scipy(robust_problem)
    ref = robust_objective(robust_problem.cost_draws, oracle.rho_bps,
                           robust_problem.cvar_alpha,
                           robust_problem.cvar_weight)
    got = robust_objective(robust_problem.cost_draws, pdhg_plan.rho_bps,
                           robust_problem.cvar_alpha,
                           robust_problem.cvar_weight)
    assert abs(got - ref) <= 1e-6 * abs(ref)
    assert check_plan(robust_problem, pdhg_plan.rho_bps,
                      rel_tol=1e-5).feasible
    assert pdhg_plan.meta["backend"] == "pdhg-robust"
    assert pdhg_plan.meta["objective_robust"] == pytest.approx(got)


def test_warm_start_resumes_and_keeps_parity(robust_problem, pdhg_plan):
    warm = pdhg_plan.meta["warm_state"]
    rewarm = solve_robust(robust_problem, PARITY_CFG,
                          x0_bps=warm["x_bps"], u0=warm["u"], v0=warm["v"])
    assert rewarm.meta["warm_started"]
    assert rewarm.meta["iterations"] < pdhg_plan.meta["iterations"]
    assert rewarm.meta["objective_robust"] == pytest.approx(
        pdhg_plan.meta["objective_robust"], rel=1e-5)


# ---------------------------------------------------------------- policy

def test_registry_exposes_robust_policy():
    assert "lints-robust" in api.available_policies()
    pol = api.get_policy("lints-robust")
    assert isinstance(pol, RobustPolicy)
    assert pol.config.backend == "scipy"          # LinTSConfig-style default
    variant = api.get_policy("lints-robust",
                             config=RobustConfig(n_draws=4, sigma=0.3))
    assert variant.config.n_draws == 4


def test_policy_plans_plain_problem_via_scipy_backend():
    base = build_problem(_requests(), _traces(), 2.0)
    plan = api.get_policy("lints-robust").plan(base)
    assert isinstance(plan, Plan)
    assert plan.meta["policy"] == "lints-robust"
    assert plan.meta["solver_status"] == "scipy"
    assert plan.meta["backend"] == "scipy-highs-robust"
    assert "objective_robust" in plan.meta
    assert check_plan(base, plan.rho_bps, rel_tol=1e-5).feasible


def test_policy_non_resilient_dispatches_backend():
    base = build_problem(_requests(), _traces(), 2.0)
    plan = RobustPolicy().plan_incremental(base, resilient=False)
    assert plan.meta["backend"] == "scipy-highs-robust"


def test_ladder_scipy_backend_faults_land_on_heuristic():
    """Poisoning the (first) scipy rung must drop to EDF, recorded."""
    base = build_problem(_requests(), _traces(), 2.0)
    plan = RobustPolicy().plan_incremental(base, inject="nan")
    assert plan.meta["solver_status"] == "heuristic"
    assert [a["rung"] for a in plan.meta["solver_ladder"]] == ["scipy"]
    assert check_plan(base, plan.rho_bps, rel_tol=1e-5).feasible


def test_ladder_pdhg_backend_falls_through_to_oracle():
    """nan-poisoned PDHG + retry rungs land on the scipy oracle; the
    poisoned rungs never run a real solve, so this stays cheap."""
    from repro.core.faults import SolverFault

    base = build_problem(_requests(), _traces(), 2.0)
    pol = RobustPolicy(RobustConfig(backend="pdhg"))
    plan = pol.plan_incremental(base,
                                inject=SolverFault(0, mode="nan", rungs=2))
    assert plan.meta["solver_status"] == "scipy"
    assert [a["rung"] for a in plan.meta["solver_ladder"]] \
        == ["pdhg", "pdhg-retry"]
    assert check_plan(base, plan.rho_bps, rel_tol=1e-5).feasible


def test_wrap_problem_lead_ramp_scales_dispersion():
    reqs = _requests()
    traces = _traces()
    base = build_problem(reqs, traces, 2.0)
    now = min(int(r.offset_slots) for r in reqs)
    pol = RobustPolicy(RobustConfig(ramp_slots=12))
    rp = pol.wrap_problem(base, reqs, traces)
    point = np.where(base.mask,
                     np.stack([traces.path_intensity(r.path, r.weights)
                               for r in reqs]), 0.0)
    # at/before the replan slot the (masked) draws ARE the point forecast...
    np.testing.assert_allclose(rp.cost_draws[:, :, :now + 1],
                               np.broadcast_to(point[None, :, :now + 1],
                                               rp.cost_draws[:, :, :now + 1]
                                               .shape), rtol=1e-12)
    # ...and dispersion grows with lead time until the ramp saturates
    disp = np.abs(rp.cost_draws - point[None]).mean(axis=(0, 1))
    far = pol.wrap_problem(base, reqs, traces)   # deterministic
    np.testing.assert_array_equal(rp.cost_draws, far.cost_draws)
    uniform = RobustPolicy(RobustConfig(ramp_slots=0)) \
        .wrap_problem(base, reqs, traces)
    disp_u = np.abs(uniform.cost_draws - point[None]).mean(axis=(0, 1))
    assert disp[now + 1] < disp_u[now + 1]       # ramped < uniform near now
    sat = now + 12
    if sat < base.n_slots:
        np.testing.assert_allclose(disp[sat:], disp_u[sat:], rtol=1e-9)


# ---------------------------------------------------------------- replay

def _replay_requests(m=32, n=3, seed=5):
    rng = np.random.default_rng(seed)
    zones = ("US-NM", "US-WY", "US-SD")
    reqs = []
    for i in range(n):
        src, dst = rng.choice(zones, size=2, replace=False)
        arrival = int(rng.integers(0, m // 4))
        reqs.append(TransferRequest(
            request_id=f"t{i}", size_gb=float(rng.uniform(100, 300)),
            path=(str(src), str(dst)), offset_slots=arrival,
            deadline_slots=int(rng.integers(m // 2, m - 1))))
    return reqs


def test_rolling_horizon_replay_smoke():
    actual = make_trace_set(ZONES, hours=8, seed=2)
    rep = rolling_horizon_replay(_replay_requests(), actual,
                                 capacity_gbps=2.0, policy="lints-robust",
                                 sigma=0.15, seed=7, revise_every=6,
                                 max_slots=32)
    assert rep["completed"] == 3
    assert rep["sla_violations"] == 0
    assert rep["forecast_revisions"] >= 2
    assert rep["replans"]["count"] >= 2
    assert rep["sigma"] == 0.15 and rep["revise_every"] == 6


def test_forecast_with_lead_noise_reveals_actuals():
    actual = make_trace_set(ZONES, hours=8, seed=2)
    fc = forecast_with_lead_noise(actual, sigma=0.3, seed=4, now_slot=10,
                                  ramp_slots=8)
    for z, t in actual.zone_slots.items():
        got = fc.zone_slots[z]
        np.testing.assert_allclose(got[:11], t[:11])   # revealed slots exact
        assert not np.allclose(got[19:], t[19:])       # far slots noisy
    # the error field is frozen: revising only slides the boundary
    fc2 = forecast_with_lead_noise(actual, sigma=0.3, seed=4, now_slot=18,
                                   ramp_slots=8)
    z0 = ZONES[0]
    a = actual.zone_slots[z0]
    eps1 = fc.zone_slots[z0][26:] / a[26:]             # both fully ramped
    eps2 = fc2.zone_slots[z0][26:] / a[26:]
    np.testing.assert_allclose(eps1, eps2, rtol=1e-12)


def test_chaos_replay_reproducible():
    """CI chaos tier: the full replay loop (chaos faults + lead noise +
    robust replans) must be exactly reproducible under one seed."""
    seed = int(os.environ.get("REPRO_CHAOS_SEED", "1337"))
    actual = make_trace_set(ZONES, hours=8, seed=2)
    faults = FaultSchedule.chaos(seed, n_slots=32, zones=ZONES,
                                 n_link_faults=0, n_forecast_faults=1,
                                 n_solver_faults=1)

    def once():
        return rolling_horizon_replay(
            _replay_requests(), actual, capacity_gbps=2.0,
            policy="lints-robust", sigma=0.15, seed=seed % 1000,
            revise_every=6, max_slots=32, faults=faults)

    a, b = once(), once()
    assert a["total_emissions_kg"] == pytest.approx(
        b["total_emissions_kg"], rel=1e-12)
    assert a["sla_violations"] == b["sla_violations"]
    assert a["completed"] == b["completed"]
    assert a["replans"]["count"] == b["replans"]["count"]
